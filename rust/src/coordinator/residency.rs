//! Cross-iteration device residency for the coordinator (PR 4).
//!
//! Iterative reconstruction calls the same forward/backprojection every
//! iteration on data that barely changes: the measured projections are
//! constant, only the volume updates. The stateless executors re-stage
//! every input host→device on every call — exactly the redundant traffic
//! hierarchical-communication schemes (Hidayetoğlu et al., arXiv
//! 2009.07226) eliminate. This module adds the missing state:
//!
//! * [`ResidencyCache`] — a per-device, memory-budget-aware cache of
//!   staged buffers. Entries are keyed by `(op, unit, source id)` where a
//!   unit is the full image (angle-split FP) or an angle-chunk range (BP
//!   input), and carry the source's **epoch**: every host-side write
//!   through [`TrackedVolume::write`]/[`TrackedProjections::write`] bumps
//!   the epoch, so a stale device copy simply stops matching — stale
//!   reuse is impossible by construction. A budget-driven LRU evicts when
//!   the per-device residency budget (device RAM minus the operators'
//!   transient working set) would be exceeded.
//! * [`ReconSession`] — a handle bundling one geometry's FP/BP [`Plan`]s,
//!   the [`MultiGpu`] context and the cache. The iterative algorithms
//!   drive their loops through it instead of the stateless
//!   `MultiGpu::forward`/`backward`:
//!   - `forward(&TrackedVolume)` skips the per-device image upload when
//!     the volume is unchanged since it was last staged, and publishes
//!     its output chunks as device-resident for the next backprojection
//!     (each device keeps the chunks *it* computed);
//!   - `backward(&TrackedProjections)` skips the chunk uploads whose
//!     `(id, epoch)` is already resident;
//!   - `backward_residual(&b, &ax)` models the paper-style iterative
//!     update `Aᵀ(b − Ax)`: the constant measurement `b` stays resident
//!     across iterations (staged once), each device already holds its own
//!     share of `Ax` from the producing forward call, and the subtraction
//!     runs on-device at accumulation cost. From the second iteration on,
//!     the only projection traffic is `Ax` chunks a device did not itself
//!     compute — **zero redundant staging**.
//!
//! Only the *simulated* schedule changes (skipped H2D events, shorter
//! makespans, honest ledger accounting via `SimNode::reserve`); the real
//! numeric path runs the identical pipelined executor on host-resident
//! arrays, so results are bit-identical with the cache on or off — the
//! parity tests below pin that.
//!
//! ## Modeled limitations (documented, not bugs)
//!
//! * Image-split plans cycle every slab through one staging allocation
//!   because the slabs do not fit simultaneously — slab residency is
//!   structurally impossible within the budget, so those stagings always
//!   count as misses (the hit-rate stays honest in the memory-starved
//!   regime).
//! * The budget is conservative: it reserves the worst-case transient
//!   working set of *both* operators (including the angle-split FP's full
//!   image), so a resident buffer can never cause a simulated OOM.
//! * Each `ReconSession` is an independent residency domain. Algorithms
//!   that interleave several geometries (OS-SART's angle subsets) hold
//!   one session per subset; in a real deployment the subsets would
//!   compete for device RAM, which the per-session budget approximates
//!   only if the caller sizes budgets accordingly.

// The residency cache is keyed for O(1) hit checks; the one iteration
// (LRU min_by_key) breaks ties by a unique monotone clock, so map order
// never reaches a schedule (see rust/clippy.toml).
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;

use crate::geometry::Geometry;
use crate::kernels::scratch;
use crate::volume::{ProjInput, TrackedProjections, TrackedVolume, Volume};

use super::error::ReconError;
use super::executor::{ExecMode, MultiGpu, OpStats};
use super::splitter::{plan_backward, plan_forward, plan_ooc_pair, Plan};

/// Which operator staged a cached unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Forward projection.
    Fp,
    /// Backprojection.
    Bp,
}

/// The staged unit a cache entry covers. Chunks are keyed by their
/// *angle range* (not a chunk index) because the FP and BP plans chunk
/// the angles at different granularities — a range can never be confused
/// between plans.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UnitKey {
    /// The whole volume, resident per device (angle-split forward).
    Image,
    /// Projection angles `[a0, a1)` (backprojection input chunk).
    Chunk { a0: usize, a1: usize },
}

/// Identity + epoch of the host buffer a device copy was staged from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SourceTag {
    /// Process-unique buffer identity (from `TrackedVolume::id` et al.).
    pub id: u64,
    /// Write counter of the host buffer at staging time.
    pub epoch: u64,
}

/// Hit/miss accounting for the residency cache, reported per operator
/// call in [`OpStats::residency`] and cumulatively on [`ReconSession`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResidencyStats {
    /// Stagings satisfied from a resident device copy (H2D skipped).
    pub hits: u64,
    /// Stagings that had to transfer (fresh data, stale epoch, or an
    /// uncacheable unit).
    pub misses: u64,
    /// Bytes of transfers skipped by hits.
    pub bytes_saved: u64,
    /// Entries evicted by the budget-driven LRU.
    pub evictions: u64,
    /// Simulated seconds of transfer skipped (costmodel `copy_time_s`
    /// applied to every hit).
    pub transfer_saved_s: f64,
}

impl ResidencyStats {
    /// Field-wise `self − earlier` (both must be cumulative snapshots of
    /// the same cache, `earlier` taken first).
    pub fn delta_since(&self, earlier: &ResidencyStats) -> ResidencyStats {
        ResidencyStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            bytes_saved: self.bytes_saved - earlier.bytes_saved,
            evictions: self.evictions - earlier.evictions,
            transfer_saved_s: self.transfer_saved_s - earlier.transfer_saved_s,
        }
    }

    /// Field-wise accumulate.
    pub fn merge(&mut self, other: &ResidencyStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.bytes_saved += other.bytes_saved;
        self.evictions += other.evictions;
        self.transfer_saved_s += other.transfer_saved_s;
    }
}

type EntryKey = (OpKind, UnitKey, u64);

#[derive(Clone, Debug)]
struct Entry {
    src: SourceTag,
    bytes: u64,
    last_use: u64,
}

#[derive(Clone, Debug)]
struct DeviceCache {
    entries: HashMap<EntryKey, Entry>,
    used: u64,
    budget: u64,
}

/// Per-device cache of staged device buffers; see the module docs.
#[derive(Clone, Debug)]
pub struct ResidencyCache {
    per_device: Vec<DeviceCache>,
    /// Monotonic logical clock ordering uses for LRU eviction.
    clock: u64,
    stats: ResidencyStats,
}

impl ResidencyCache {
    /// A cache for `n_dev` devices with the same residency `budget` each
    /// (bytes of device RAM available beyond the operators' working set).
    pub fn new(n_dev: usize, budget: u64) -> Self {
        Self::with_budgets(vec![budget; n_dev])
    }

    /// Per-device budgets (tests use asymmetric ones).
    pub fn with_budgets(budgets: Vec<u64>) -> Self {
        Self {
            per_device: budgets
                .into_iter()
                .map(|budget| DeviceCache { entries: HashMap::new(), used: 0, budget })
                .collect(),
            clock: 0,
            stats: ResidencyStats::default(),
        }
    }

    /// Cumulative statistics snapshot.
    pub fn stats(&self) -> ResidencyStats {
        self.stats
    }

    /// Bytes currently resident on device `dev`.
    pub fn resident_bytes(&self, dev: usize) -> u64 {
        self.per_device[dev].used
    }

    /// The residency budget of device `dev`.
    pub fn budget(&self, dev: usize) -> u64 {
        self.per_device[dev].budget
    }

    /// Whether `(op, unit)` from exactly `src` is resident on `dev`.
    pub fn contains(&self, dev: usize, op: OpKind, unit: UnitKey, src: SourceTag) -> bool {
        self.per_device[dev]
            .entries
            .get(&(op, unit, src.id))
            .is_some_and(|e| e.src.epoch == src.epoch)
    }

    /// Record one staging of `unit` from `src` on device `dev`. Returns
    /// `true` on a hit (resident and epoch-fresh: the transfer can be
    /// skipped). On a miss the unit is transferred and then kept resident
    /// if it fits the budget (evicting LRU entries as needed); a stale
    /// copy of the same buffer is dropped first, so an outdated epoch can
    /// never be reused later.
    ///
    /// Pure hit/miss accounting: transfer savings are credited by the
    /// caller via [`ResidencyCache::add_saved`], because what a hit is
    /// worth depends on what the uncached schedule would have staged
    /// (residual mode nets two operands against one baseline chunk).
    pub fn stage(
        &mut self,
        dev: usize,
        op: OpKind,
        unit: UnitKey,
        src: SourceTag,
        bytes: u64,
    ) -> bool {
        self.clock += 1;
        let clock = self.clock;
        let key = (op, unit, src.id);
        let dc = &mut self.per_device[dev];
        if let Some(e) = dc.entries.get_mut(&key) {
            if e.src.epoch == src.epoch {
                e.last_use = clock;
                self.stats.hits += 1;
                return true;
            }
            // stale epoch: the device copy is outdated — drop it
            let stale_bytes = e.bytes;
            dc.entries.remove(&key);
            dc.used -= stale_bytes;
        }
        self.stats.misses += 1;
        self.insert(dev, key, src, bytes);
        false
    }

    /// Count stagings of units that are structurally uncacheable (e.g.
    /// image-split slabs cycling through one allocation) so the hit rate
    /// reflects *all* staging traffic, not just the cacheable part.
    pub fn note_uncacheable_misses(&mut self, n: u64) {
        self.stats.misses += n;
    }

    /// Whether a unit of `bytes` could ever be kept resident on `dev`.
    /// Exactly matches [`ResidencyCache::stage`]'s insert outcome: the
    /// LRU can always evict down to zero, so only the budget bounds it.
    pub fn can_cache(&self, dev: usize, bytes: u64) -> bool {
        bytes <= self.per_device[dev].budget
    }

    /// Credit transfer savings against the uncached baseline (see
    /// [`ResidencyCache::stage`] — residual mode nets its two operands
    /// against the *single* residual chunk the uncached executor would
    /// have staged, so crediting per hit would double-count the win).
    pub fn add_saved(&mut self, bytes: u64, secs: f64) {
        self.stats.bytes_saved += bytes;
        self.stats.transfer_saved_s += secs;
    }

    /// Register a buffer the device already holds (an operator *output*
    /// left resident, e.g. forward-projection chunks). No hit/miss is
    /// counted — nothing was staged — but the entry competes for budget
    /// like any other.
    pub fn publish(&mut self, dev: usize, op: OpKind, unit: UnitKey, src: SourceTag, bytes: u64) {
        self.clock += 1;
        let key = (op, unit, src.id);
        let dc = &mut self.per_device[dev];
        if let Some(old) = dc.entries.remove(&key) {
            dc.used -= old.bytes;
        }
        self.insert(dev, key, src, bytes);
    }

    /// Drop every entry sourced from buffer `id` on all devices (the
    /// producing call's device buffers are being reused).
    pub fn forget_source(&mut self, id: u64) {
        for dc in &mut self.per_device {
            let dead: Vec<EntryKey> =
                dc.entries.keys().filter(|k| k.2 == id).copied().collect();
            for k in dead {
                if let Some(e) = dc.entries.remove(&k) {
                    dc.used -= e.bytes;
                }
            }
        }
    }

    /// Memory-pressure eviction (ISSUE 8, rung 1 of the degradation
    /// ladder): drop **every** entry on device `dev`, returning how many
    /// were evicted. Unlike the budget-driven LRU this is caller-forced —
    /// an allocation failed, so resident bytes must make way for the
    /// operator's working set. Evictions are counted in the stats like
    /// LRU ones; correctness is unaffected (the next staging simply
    /// misses and re-transfers).
    pub fn evict_device(&mut self, dev: usize) -> usize {
        let dc = &mut self.per_device[dev];
        let n = dc.entries.len();
        dc.entries.clear();
        dc.used = 0;
        self.stats.evictions += n as u64;
        n
    }

    fn insert(&mut self, dev: usize, key: EntryKey, src: SourceTag, bytes: u64) {
        let clock = self.clock;
        let dc = &mut self.per_device[dev];
        if bytes > dc.budget {
            return; // can never fit — stream-only unit
        }
        while dc.used + bytes > dc.budget {
            let Some((&lru, _)) = dc.entries.iter().min_by_key(|(_, e)| e.last_use) else {
                break;
            };
            let Some(e) = dc.entries.remove(&lru) else { break };
            dc.used -= e.bytes;
            self.stats.evictions += 1;
        }
        if dc.used + bytes <= dc.budget {
            dc.entries.insert(key, Entry { src, bytes, last_use: clock });
            dc.used += bytes;
        }
    }
}

// ---------------------------------------------------------------------------
// per-call residency decisions handed to the simulated schedules
// ---------------------------------------------------------------------------

/// Forward-call residency decisions (computed against the cache before
/// the simulated schedule replays).
#[derive(Clone, Debug)]
pub(crate) struct FpResidency {
    /// Per device: the resident image is epoch-fresh — skip its upload.
    pub skip_image_h2d: Vec<bool>,
    /// Per device: the image is cached after this call — the schedule
    /// must not free it at operator end.
    pub keep_image: Vec<bool>,
    /// Per device: carried-over resident bytes to charge to the ledger.
    pub reserve: Vec<u64>,
}

/// One `(device, slab, chunk)` staging decision for the backprojection.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ChunkStage {
    /// Bytes this launch still has to move host→device (0 = all inputs
    /// resident).
    pub h2d_bytes: u64,
    /// On-device residual subtraction time (`b − Ax`), charged once per
    /// device×chunk in residual mode.
    pub subtract_s: f64,
}

/// Backward-call residency decisions, indexed `[device][slab][chunk]`.
#[derive(Clone, Debug)]
pub(crate) struct BpResidency {
    /// Per-chunk staging decision.
    pub stage: Vec<Vec<Vec<ChunkStage>>>,
    /// Per-device bytes reserved for resident chunks.
    pub reserve: Vec<u64>,
}

fn plan_fp_residency(
    plan: &Plan,
    g: &Geometry,
    ctx: &MultiGpu,
    cache: &mut ResidencyCache,
    src: SourceTag,
) -> FpResidency {
    let n_dev = ctx.n_gpus;
    let mut skip = vec![false; n_dev];
    let mut keep = vec![false; n_dev];
    if plan.full_image_per_device {
        let bytes = g.volume_bytes();
        let saved = ctx.cost.copy_time_s(bytes, plan.pin_image);
        for (d, (sk, kp)) in skip.iter_mut().zip(keep.iter_mut()).enumerate() {
            *sk = cache.stage(d, OpKind::Fp, UnitKey::Image, src, bytes);
            if *sk {
                cache.add_saved(bytes, saved);
            }
            *kp = cache.contains(d, OpKind::Fp, UnitKey::Image, src);
        }
    } else {
        // image-split: slabs cycle through one staging allocation and can
        // never stay resident within the budget — count the traffic
        let stagings: u64 = plan.per_device.iter().map(|d| d.slabs.len() as u64).sum();
        cache.note_uncacheable_misses(stagings);
    }
    let reserve = fp_reserve_bytes(plan, g, cache, &skip, &keep);
    FpResidency { skip_image_h2d: skip, keep_image: keep, reserve }
}

/// Carried-over bytes to pre-charge per device. A freshly-staged image
/// (miss that got cached) is excluded: the schedule's own `alloc` charges
/// it this call, and `keep_image` retains the allocation afterwards.
fn fp_reserve_bytes(
    plan: &Plan,
    g: &Geometry,
    cache: &ResidencyCache,
    skip: &[bool],
    keep: &[bool],
) -> Vec<u64> {
    (0..skip.len())
        .map(|d| {
            let mut r = cache.resident_bytes(d);
            if plan.full_image_per_device && keep[d] && !skip[d] {
                r = r.saturating_sub(g.volume_bytes());
            }
            r
        })
        .collect()
}

fn plan_bp_residency(
    plan: &Plan,
    g: &Geometry,
    ctx: &MultiGpu,
    cache: &mut ResidencyCache,
    sources: &[SourceTag],
) -> BpResidency {
    let n_dev = ctx.n_gpus;
    let residual = sources.len() > 1;
    let mut stage = Vec::with_capacity(n_dev);
    for d in 0..n_dev {
        let n_slabs = plan.per_device[d].slabs.len();
        let mut first_pass = vec![true; plan.angle_chunks.len()];
        let mut per_slab = Vec::with_capacity(n_slabs);
        for _s in 0..n_slabs {
            let mut per_chunk = Vec::with_capacity(plan.angle_chunks.len());
            for (c, ch) in plan.angle_chunks.iter().enumerate() {
                let bytes = ch.len() as u64 * g.single_proj_bytes();
                let unit = UnitKey::Chunk { a0: ch.a0, a1: ch.a1 };
                let saved = ctx.cost.copy_time_s(bytes, plan.pin_image);
                let (h2d_bytes, on_device) = if !residual {
                    let hit = cache.stage(d, OpKind::Bp, unit, sources[0], bytes);
                    if hit {
                        cache.add_saved(bytes, saved);
                    }
                    (if hit { 0 } else { bytes }, false)
                } else if cache.can_cache(d, bytes) {
                    // invest: stage b once (resident for every later
                    // iteration) and the fresh Ax share, subtract
                    // on-device — the residual never crosses the bus.
                    // Savings are netted against the baseline's single
                    // residual-chunk staging, not credited per operand.
                    let mut h2d = 0;
                    for &src in sources {
                        if !cache.stage(d, OpKind::Bp, unit, src, bytes) {
                            h2d += bytes;
                        }
                    }
                    let actual_s =
                        if h2d > 0 { ctx.cost.copy_time_s(h2d, plan.pin_image) } else { 0.0 };
                    let saved_s = (saved - actual_s).max(0.0);
                    let saved_b = bytes.saturating_sub(h2d);
                    if saved_b > 0 || saved_s > 0.0 {
                        cache.add_saved(saved_b, saved_s);
                    }
                    (h2d, true)
                } else {
                    // the device can never keep b: stream the host-formed
                    // residual exactly like the uncached executor (no
                    // double staging, no on-device subtraction)
                    cache.note_uncacheable_misses(1);
                    (bytes, false)
                };
                let subtract_s = if on_device && first_pass[c] {
                    first_pass[c] = false;
                    ctx.cost.accum_kernel_s(bytes)
                } else {
                    0.0
                };
                per_chunk.push(ChunkStage { h2d_bytes, subtract_s });
            }
            per_slab.push(per_chunk);
        }
        stage.push(per_slab);
    }
    let reserve = (0..n_dev).map(|d| cache.resident_bytes(d)).collect();
    BpResidency { stage, reserve }
}

/// Leave the forward call's output chunks resident on the devices that
/// computed them, at the *backprojection* plan's chunk granularity: a BP
/// chunk is resident on device `d` iff its angle range lies entirely
/// within `d`'s forward share.
fn publish_fp_outputs(
    fp_plan: &Plan,
    bp_plan: &Plan,
    g: &Geometry,
    n_dev: usize,
    cache: &mut ResidencyCache,
    src: SourceTag,
) {
    let shares = fp_plan.chunk_shares(n_dev);
    for (d, &(c0, c1)) in shares.iter().enumerate() {
        if c1 <= c0 {
            continue;
        }
        let a_lo = fp_plan.angle_chunks[c0].a0;
        let a_hi = fp_plan.angle_chunks[c1 - 1].a1;
        for ch in &bp_plan.angle_chunks {
            if ch.a0 >= a_lo && ch.a1 <= a_hi {
                let bytes = ch.len() as u64 * g.single_proj_bytes();
                cache.publish(d, OpKind::Bp, UnitKey::Chunk { a0: ch.a0, a1: ch.a1 }, src, bytes);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Sparse system-matrix shards (ISSUE 10)
// ---------------------------------------------------------------------------

/// Counters for the sparse shard store, the matrix analogue of
/// [`ResidencyStats`]: `builds` counts traversal+assembly runs, `hits`
/// counts launches served by an already-built shard. The "zero matrix
/// rebuilds on iteration 2+" acceptance test asserts that `builds`
/// stops growing after the first iteration.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SparseShardStats {
    /// Shards built (one Siddon traversal + CSR/CSC assembly each).
    pub builds: u64,
    /// Kernel launches that reused a cached shard.
    pub hits: u64,
    /// Shards evicted by the byte-budget LRU.
    pub evictions: u64,
    /// Bytes of shard storage currently held.
    pub resident_bytes: u64,
}

struct ShardEntry {
    matrix: std::sync::Arc<crate::kernels::sparse::SparseSystemMatrix>,
    bytes: u64,
    last_use: u64,
}

struct ShardState {
    /// Shards keyed by sub-geometry fingerprint. A `BTreeMap` so that
    /// any future iteration over the store is deterministic (the
    /// repo-wide no-hash-maps-near-plans rule).
    shards: std::collections::BTreeMap<u64, ShardEntry>,
    used: u64,
    clock: u64,
    stats: SparseShardStats,
    /// `(op, plan-fingerprint)` pairs the *simulated* timeline has
    /// already charged a matrix build for — the SimOnly analogue of the
    /// real path's shard reuse (see `CostModel::sparse_setup_s`).
    sim_warm: std::collections::BTreeSet<(u8, u64)>,
}

/// Host-side store of slab-local CSR system matrices for the
/// [`Backend::Sparse`](super::executor::Backend) projector, shared
/// across clones of a [`MultiGpu`] context.
///
/// Each splitter-emitted slab×chunk unit executes against one
/// [`SparseSystemMatrix`](crate::kernels::sparse::SparseSystemMatrix)
/// shard, keyed by the unit sub-geometry's fingerprint
/// ([`crate::kernels::sparse::geometry_fingerprint`]). The sub-geometry
/// is fully determined by the `(geometry, plan)` pair, so as long as the
/// plan is stable — the steady state of every iterative loop — the 2nd+
/// iterations find every shard already built and skip the traversal
/// entirely. Pressure replanning (ISSUE 8) changes slab boundaries and
/// therefore fingerprints; the orphaned shards age out of the byte
/// budget through the LRU, and correctness is untouched (a missing
/// shard is rebuilt, never guessed).
///
/// Thread safety: device workers of the pipelined executor call
/// [`SparseShardCache::get_or_build`] concurrently. Builds run under the
/// store lock — two workers never build the same shard twice, at the
/// cost of serializing concurrent *builds* (first-iteration only; every
/// later launch is a cheap lookup). Lock poisoning is absorbed
/// (`into_inner`): the store holds plain data, and a worker that
/// panicked mid-*lookup* cannot leave a half-built shard behind because
/// entries are inserted fully constructed.
pub struct SparseShardCache {
    state: std::sync::Mutex<ShardState>,
    budget: u64,
}

impl SparseShardCache {
    /// Default shard budget: 2 GiB of host RAM. Paper-scale slabs are
    /// far below this; test geometries use kilobytes.
    pub const DEFAULT_BUDGET: u64 = 2 << 30;

    /// A store with the default byte budget.
    pub fn new() -> Self {
        Self::with_budget(Self::DEFAULT_BUDGET)
    }

    /// A store bounded to `budget` bytes of shard storage (LRU beyond).
    pub fn with_budget(budget: u64) -> Self {
        Self {
            state: std::sync::Mutex::new(ShardState {
                shards: std::collections::BTreeMap::new(),
                used: 0,
                clock: 0,
                stats: SparseShardStats::default(),
                sim_warm: std::collections::BTreeSet::new(),
            }),
            budget,
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> SparseShardStats {
        let s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        SparseShardStats { resident_bytes: s.used, ..s.stats }
    }

    /// The shard for unit sub-geometry `g`: served from the store when
    /// already built (a *hit*), otherwise traced and assembled now with
    /// `threads` build threads and kept for the next launch.
    pub fn get_or_build(
        &self,
        g: &Geometry,
        threads: usize,
    ) -> std::sync::Arc<crate::kernels::sparse::SparseSystemMatrix> {
        let key = crate::kernels::sparse::geometry_fingerprint(g);
        let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        s.clock += 1;
        let clock = s.clock;
        if let Some(e) = s.shards.get_mut(&key) {
            e.last_use = clock;
            s.stats.hits += 1;
            return e.matrix.clone();
        }
        let matrix =
            std::sync::Arc::new(crate::kernels::sparse::SparseSystemMatrix::build(g, threads));
        let bytes = matrix.bytes();
        s.stats.builds += 1;
        // Budget-driven LRU, mirroring `ResidencyCache::insert`. An
        // oversized shard is still returned to the caller — the launch
        // must run — it just isn't retained.
        if bytes <= self.budget {
            while s.used + bytes > self.budget {
                let Some((&lru, _)) = s.shards.iter().min_by_key(|(_, e)| e.last_use) else {
                    break;
                };
                let Some(e) = s.shards.remove(&lru) else { break };
                s.used -= e.bytes;
                s.stats.evictions += 1;
            }
            if s.used + bytes <= self.budget {
                s.shards.insert(key, ShardEntry { matrix: matrix.clone(), bytes, last_use: clock });
                s.used += bytes;
            }
        }
        matrix
    }

    /// SimOnly bookkeeping: returns whether the simulated timeline has
    /// already charged the matrix build for `(op, plan_key)` — `false`
    /// exactly once per pair, after which the pair is warm and the DES
    /// charges only SpMV time (the timing analogue of the real path's
    /// shard reuse). `plan_key` is a fingerprint over the plan's unit
    /// boundaries; see `forward::sparse_plan_key`.
    pub fn sim_op_warm(&self, op: OpKind, plan_key: u64) -> bool {
        let tag = (matches!(op, OpKind::Bp) as u8, plan_key);
        let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        !s.sim_warm.insert(tag)
    }
}

impl Default for SparseShardCache {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for SparseShardCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("SparseShardCache")
            .field("budget", &self.budget)
            .field("stats", &s)
            .finish()
    }
}

// ---------------------------------------------------------------------------
// ReconSession
// ---------------------------------------------------------------------------

/// A reconstruction session: one geometry's operator plans, the device
/// context and the cross-iteration residency state, plus cumulative
/// accounting. See the module docs for the protocol.
pub struct ReconSession {
    ctx: MultiGpu,
    g: Geometry,
    fp_plan: Plan,
    bp_plan: Plan,
    cache: ResidencyCache,
    enabled: bool,
    /// Source id of the forward output currently published as resident.
    last_fp_output: Option<u64>,
    /// Total simulated seconds across all operator calls.
    pub sim_time_s: f64,
    /// Peak simulated device memory across all calls.
    pub peak_device_bytes: u64,
    /// Cumulative residency accounting across all calls.
    pub residency: ResidencyStats,
    /// Stats of the most recent operator call (tests assert on this).
    pub last: Option<OpStats>,
}

impl ReconSession {
    /// Plan both operators for `g` on `ctx` and derive the per-device
    /// residency budget: usable device RAM minus the larger of the two
    /// operators' transient working sets.
    pub fn new(ctx: &MultiGpu, g: &Geometry) -> anyhow::Result<Self> {
        let fp_plan = plan_forward(g, ctx.n_gpus, ctx.spec.mem_bytes, &ctx.split)
            .map_err(|e| ReconError::Plan(format!("session forward plan: {e}")))?;
        let bp_plan = plan_backward(g, ctx.n_gpus, ctx.spec.mem_bytes, &ctx.split)
            .map_err(|e| ReconError::Plan(format!("session backward plan: {e}")))?;
        Ok(Self::with_plans(ctx, g, fp_plan, bp_plan))
    }

    /// An out-of-core session (PR 5): plans both operators through
    /// `splitter::plan_ooc_pair` under `host_budget` bytes of host RAM
    /// for streaming — slab boundaries aligned across FP and BP so the
    /// stores' caches hit across passes, the image-split regime forced
    /// when the volume cannot fit the budget, chunk sizes shrunk to the
    /// staging budget. Accepts RAM- and OOC-backed tracked inputs alike
    /// (a RAM input on an OOC plan is simply the parity baseline).
    ///
    /// `host_budget` bounds the *streaming staging* this session's plans
    /// add; the OOC stores' own caches are budgeted separately at store
    /// construction — size the two together against physical RAM (see
    /// `MultiGpu::forward_ooc` on the composition).
    pub fn new_ooc(ctx: &MultiGpu, g: &Geometry, host_budget: u64) -> anyhow::Result<Self> {
        let (fp_plan, bp_plan) =
            plan_ooc_pair(g, ctx.n_gpus, ctx.spec.mem_bytes, &ctx.split, host_budget)
                .map_err(|e| ReconError::Plan(format!("session ooc plans: {e}")))?;
        Ok(Self::with_plans(ctx, g, fp_plan, bp_plan))
    }

    fn with_plans(ctx: &MultiGpu, g: &Geometry, fp_plan: Plan, bp_plan: Plan) -> Self {
        let usable = (ctx.spec.mem_bytes as f64 * ctx.split.mem_fraction) as u64;
        let working_set = fp_plan.working_set_bytes(g).max(bp_plan.working_set_bytes(g));
        let budget = usable.saturating_sub(working_set);
        Self {
            ctx: ctx.clone(),
            g: g.clone(),
            fp_plan,
            bp_plan,
            cache: ResidencyCache::new(ctx.n_gpus, budget),
            enabled: true,
            last_fp_output: None,
            sim_time_s: 0.0,
            peak_device_bytes: 0,
            residency: ResidencyStats::default(),
            last: None,
        }
    }

    /// Disable the cache (every staging transfers, as pre-session code
    /// did) — the baseline side of cached-vs-uncached comparisons.
    pub fn without_residency(mut self) -> Self {
        self.enabled = false;
        self
    }

    /// The per-device residency budget, bytes.
    pub fn residency_budget(&self) -> u64 {
        self.cache.budget(0)
    }

    /// Forward projection `A·vol`. Residency: the per-device image upload
    /// is skipped when `vol` is unchanged since last staged; the output
    /// chunks are published as device-resident for the following
    /// backprojection.
    pub fn forward(&mut self, vol: &TrackedVolume) -> anyhow::Result<TrackedProjections> {
        let before = self.cache.stats();
        let res = if self.enabled {
            // the device output buffers are about to be reused: the
            // previous forward's published chunks are gone
            if let Some(prev) = self.last_fp_output.take() {
                self.cache.forget_source(prev);
            }
            let src = SourceTag { id: vol.id(), epoch: vol.epoch() };
            Some(plan_fp_residency(&self.fp_plan, &self.g, &self.ctx, &mut self.cache, src))
        } else {
            None
        };
        let (p, mut stats) = super::forward::run_with(
            &self.ctx,
            &self.g,
            Some(vol.as_input()),
            ExecMode::Full,
            &self.fp_plan,
            res.as_ref(),
        )?;
        let p = p.ok_or_else(|| {
            ReconError::Input("Full mode did not return projections".into())
        })?;
        let out = TrackedProjections::new(p);
        if self.enabled && self.fp_plan.full_image_per_device {
            let src = SourceTag { id: out.id(), epoch: out.epoch() };
            publish_fp_outputs(
                &self.fp_plan,
                &self.bp_plan,
                &self.g,
                self.ctx.n_gpus,
                &mut self.cache,
                src,
            );
            self.last_fp_output = Some(out.id());
        }
        // a pressure-ladder retry ran without the precomputed residency
        // decisions: the device buffers those decisions assumed resident
        // were sacrificed, so drop them from the cache too (next call
        // restages — a miss, never a wrong answer)
        if stats.degradation.evictions > 0 {
            for d in 0..self.ctx.n_gpus {
                self.cache.evict_device(d);
            }
        }
        // delta taken after publishing, so evictions the publication
        // causes are attributed to this call instead of vanishing into
        // the next call's baseline snapshot
        stats.residency = self.cache.stats().delta_since(&before);
        self.account(stats);
        Ok(out)
    }

    /// Backprojection `Aᵀ·proj`. Chunk uploads whose `(id, epoch)` is
    /// already resident are skipped; missed chunks stay resident for the
    /// next call (budget permitting).
    pub fn backward(&mut self, proj: &TrackedProjections) -> anyhow::Result<Volume> {
        let src = SourceTag { id: proj.id(), epoch: proj.epoch() };
        self.backward_inner(proj.as_input(), &[src])
    }

    /// The iterative update `Aᵀ(b − ax)` with residual formation modeled
    /// on-device: `b` stays resident across iterations, each device
    /// already holds its own share of `ax` (the session's forward
    /// output), and the subtraction costs an accumulation kernel. Returns
    /// the backprojected update and `‖b − ax‖₂`.
    ///
    /// Numerically this computes the residual host-side and runs the
    /// standard pipelined executor on it — bit-identical to doing the
    /// same two steps without a session.
    pub fn backward_residual(
        &mut self,
        b: &TrackedProjections,
        ax: &TrackedProjections,
    ) -> anyhow::Result<(Volume, f64)> {
        if b.is_ooc() || ax.is_ooc() {
            return Err(ReconError::Input(
                "backward_residual requires RAM-backed projections (the residual is formed \
                 host-side); stream OOC inputs through backward() instead"
                    .into(),
            )
            .into());
        }
        let bp = b.get();
        let ap = ax.get();
        if bp.data.len() != ap.data.len() {
            return Err(ReconError::Input(format!(
                "backward_residual: b has {} samples but ax has {}",
                bp.data.len(),
                ap.data.len()
            ))
            .into());
        }
        let mut r = scratch::take_projections(bp.nu, bp.nv, bp.n_angles);
        for ((rv, bv), av) in r.data.iter_mut().zip(&bp.data).zip(&ap.data) {
            *rv = bv - av;
        }
        let norm = r.norm2();
        let sources = [
            SourceTag { id: b.id(), epoch: b.epoch() },
            SourceTag { id: ax.id(), epoch: ax.epoch() },
        ];
        let vol = self.backward_inner(ProjInput::Ram(&r), &sources)?;
        scratch::recycle_projections(r);
        Ok((vol, norm))
    }

    fn backward_inner(
        &mut self,
        proj: ProjInput<'_>,
        sources: &[SourceTag],
    ) -> anyhow::Result<Volume> {
        let before = self.cache.stats();
        let res = if self.enabled {
            Some(plan_bp_residency(&self.bp_plan, &self.g, &self.ctx, &mut self.cache, sources))
        } else {
            None
        };
        let (v, mut stats) = super::backward::run_with(
            &self.ctx,
            &self.g,
            Some(proj),
            ExecMode::Full,
            &self.bp_plan,
            res.as_ref(),
        )?;
        if stats.degradation.evictions > 0 {
            for d in 0..self.ctx.n_gpus {
                self.cache.evict_device(d);
            }
        }
        stats.residency = self.cache.stats().delta_since(&before);
        self.account(stats);
        v.ok_or_else(|| ReconError::Input("Full mode did not return the volume".into()).into())
    }

    /// Recycle a tracked projection buffer through the `kernels::scratch`
    /// arena *and* drop any device-resident copies of it from the cache:
    /// the host buffer is gone, so keeping entries would charge dead
    /// bytes to the ledger (and squeeze the LRU budget) forever.
    pub fn recycle_projections(&mut self, p: TrackedProjections) {
        self.cache.forget_source(p.id());
        if self.last_fp_output == Some(p.id()) {
            self.last_fp_output = None;
        }
        scratch::recycle_projections(p.into_inner());
    }

    fn account(&mut self, stats: OpStats) {
        self.sim_time_s += stats.makespan_s;
        self.peak_device_bytes = self.peak_device_bytes.max(stats.peak_device_bytes);
        self.residency.merge(&stats.residency);
        self.last = Some(stats);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::{ExecMode, MultiGpu};
    use crate::coordinator::splitter::{image_split_mem, SplitConfig};
    use crate::phantom;

    fn tag(id: u64, epoch: u64) -> SourceTag {
        SourceTag { id, epoch }
    }

    #[test]
    fn cache_hit_only_on_matching_id_and_epoch() {
        let mut c = ResidencyCache::new(1, 1 << 20);
        let unit = UnitKey::Chunk { a0: 0, a1: 9 };
        assert!(!c.stage(0, OpKind::Bp, unit, tag(1, 0), 100), "first staging misses");
        assert!(c.stage(0, OpKind::Bp, unit, tag(1, 0), 100), "unchanged source hits");
        // epoch bump = host write: the resident copy must stop matching
        assert!(!c.stage(0, OpKind::Bp, unit, tag(1, 1), 100), "stale epoch misses");
        assert!(c.stage(0, OpKind::Bp, unit, tag(1, 1), 100), "restaged copy hits again");
        // the stale epoch can never hit again
        assert!(!c.stage(0, OpKind::Bp, unit, tag(1, 0), 100));
        // a different buffer at the same unit is a distinct entry
        assert!(!c.stage(0, OpKind::Bp, unit, tag(2, 0), 100));
        assert!(c.stage(0, OpKind::Bp, unit, tag(2, 0), 100));
        let s = c.stats();
        assert_eq!(s.hits, 3);
        assert_eq!(s.misses, 4);
        // savings are credited by the caller, not by stage()
        assert_eq!(s.bytes_saved, 0);
        c.add_saved(300, 3.0);
        assert_eq!(c.stats().bytes_saved, 300);
        assert!((c.stats().transfer_saved_s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn cache_lru_evicts_under_tight_budget() {
        // budget of 250 bytes: holds two 100-byte chunks, not three
        let mut c = ResidencyCache::new(1, 250);
        let u = |i: usize| UnitKey::Chunk { a0: i, a1: i + 1 };
        assert!(!c.stage(0, OpKind::Bp, u(0), tag(1, 0), 100));
        assert!(!c.stage(0, OpKind::Bp, u(1), tag(2, 0), 100));
        assert_eq!(c.resident_bytes(0), 200);
        // touch chunk 0 so chunk 1 becomes the LRU
        assert!(c.stage(0, OpKind::Bp, u(0), tag(1, 0), 100));
        // inserting chunk 2 must evict chunk 1 (LRU), not chunk 0
        assert!(!c.stage(0, OpKind::Bp, u(2), tag(3, 0), 100));
        assert_eq!(c.resident_bytes(0), 200);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.contains(0, OpKind::Bp, u(0), tag(1, 0)), "recently-used survives");
        assert!(!c.contains(0, OpKind::Bp, u(1), tag(2, 0)), "LRU evicted");
        assert!(c.contains(0, OpKind::Bp, u(2), tag(3, 0)));
        // a unit bigger than the whole budget is never cached
        assert!(!c.stage(0, OpKind::Bp, u(3), tag(4, 0), 1000));
        assert!(!c.contains(0, OpKind::Bp, u(3), tag(4, 0)));
        assert_eq!(c.resident_bytes(0), 200, "oversized unit must not evict anything");
    }

    #[test]
    fn cache_pressure_evict_clears_one_device() {
        let mut c = ResidencyCache::new(2, 1 << 20);
        let u = |i: usize| UnitKey::Chunk { a0: i, a1: i + 1 };
        c.publish(0, OpKind::Bp, u(0), tag(1, 0), 64);
        c.publish(0, OpKind::Bp, u(1), tag(1, 0), 64);
        c.publish(1, OpKind::Bp, u(0), tag(1, 0), 64);
        assert_eq!(c.evict_device(0), 2);
        assert_eq!(c.resident_bytes(0), 0);
        assert!(!c.contains(0, OpKind::Bp, u(0), tag(1, 0)));
        assert!(c.contains(1, OpKind::Bp, u(0), tag(1, 0)), "other devices untouched");
        assert_eq!(c.stats().evictions, 2);
        // idempotent on an empty device
        assert_eq!(c.evict_device(0), 0);
    }

    #[test]
    fn cache_forget_source_drops_all_devices() {
        let mut c = ResidencyCache::new(2, 1 << 20);
        let u = UnitKey::Chunk { a0: 0, a1: 4 };
        c.publish(0, OpKind::Bp, u, tag(7, 0), 64);
        c.publish(1, OpKind::Bp, u, tag(7, 0), 64);
        c.publish(1, OpKind::Bp, u, tag(8, 0), 64);
        c.forget_source(7);
        assert!(!c.contains(0, OpKind::Bp, u, tag(7, 0)));
        assert!(!c.contains(1, OpKind::Bp, u, tag(7, 0)));
        assert!(c.contains(1, OpKind::Bp, u, tag(8, 0)), "other sources survive");
        assert_eq!(c.resident_bytes(0), 0);
        assert_eq!(c.resident_bytes(1), 64);
    }

    /// Device memory that forces the image-split regime for `g`.
    fn tiny_mem(g: &Geometry) -> u64 {
        image_split_mem(g, &SplitConfig::default())
    }

    fn contexts(n_gpus: usize, g: &Geometry, image_split: bool) -> MultiGpu {
        let ctx = MultiGpu::gtx1080ti(n_gpus);
        if image_split {
            ctx.with_device_mem(tiny_mem(g))
        } else {
            ctx
        }
    }

    #[test]
    fn fp_image_residency_hits_until_the_volume_is_written() {
        let g = Geometry::cone_beam(16, 10);
        let ctx = MultiGpu::gtx1080ti(2);
        let reference = ctx.forward(&g, Some(&phantom::shepp_logan(16)), ExecMode::Full)
            .unwrap()
            .0
            .unwrap();
        let mut sess = ReconSession::new(&ctx, &g).unwrap();
        let mut x = TrackedVolume::new(phantom::shepp_logan(16));

        let p1 = sess.forward(&x).unwrap();
        let s1 = sess.last.as_ref().unwrap().residency;
        assert_eq!(s1.hits, 0, "first call stages everything");
        assert_eq!(s1.misses, 2, "one image upload per device");
        assert_eq!(p1.get().data, reference.data, "residency must not change numerics");

        let p2 = sess.forward(&x).unwrap();
        let s2 = sess.last.as_ref().unwrap().residency;
        assert_eq!(s2.hits, 2, "unchanged volume: both devices reuse the resident image");
        assert_eq!(s2.misses, 0);
        assert!(s2.bytes_saved >= 2 * g.volume_bytes());
        assert!(s2.transfer_saved_s > 0.0);
        assert_eq!(p2.get().data, reference.data);
        // the cached call must be simulated-faster than the uncached one
        let t1 = sess.last.as_ref().unwrap().makespan_s;
        let (_, uncached) = ctx.forward(&g, Some(x.get()), ExecMode::Full).unwrap();
        assert!(t1 < uncached.makespan_s, "cached {t1} vs uncached {}", uncached.makespan_s);

        // host-side write bumps the epoch: stale reuse must be impossible
        x.write().data[0] += 1.0;
        let p3 = sess.forward(&x).unwrap();
        let s3 = sess.last.as_ref().unwrap().residency;
        assert_eq!(s3.hits, 0, "written volume must re-stage everywhere");
        assert_eq!(s3.misses, 2);
        let fresh = ctx.forward(&g, Some(x.get()), ExecMode::Full).unwrap().0.unwrap();
        assert_eq!(p3.get().data, fresh.data, "post-write output must track the new data");
    }

    #[test]
    fn bp_caches_unchanged_projections_across_calls() {
        let g = Geometry::cone_beam(16, 10);
        let ctx = MultiGpu::gtx1080ti(2);
        let v = phantom::shepp_logan(16);
        let p = ctx.forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
        let reference = ctx.backward(&g, Some(&p), ExecMode::Full).unwrap().0.unwrap();

        let mut sess = ReconSession::new(&ctx, &g).unwrap();
        let b = TrackedProjections::new(p);
        let v1 = sess.backward(&b).unwrap();
        let s1 = sess.last.as_ref().unwrap().residency;
        assert_eq!(s1.hits, 0);
        assert!(s1.misses > 0);
        assert_eq!(v1.data, reference.data);

        let v2 = sess.backward(&b).unwrap();
        let s2 = sess.last.as_ref().unwrap().residency;
        assert_eq!(s2.misses, 0, "unchanged projections: zero redundant staging");
        assert_eq!(s2.hits, s1.misses, "every prior staging is now a hit");
        assert_eq!(v2.data, reference.data);
    }

    /// The acceptance criterion: an iterative loop's 2nd+ iterations
    /// perform zero redundant projection staging while staying
    /// bit-identical to the uncached pipelined executor, across
    /// 1–3 simulated GPUs × angle/image split.
    #[test]
    fn iterative_loop_bit_parity_and_zero_redundant_staging() {
        let n = 16;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let truth = phantom::shepp_logan(n);
        for n_gpus in [1usize, 2, 3] {
            for image_split in [false, true] {
                let ctx = contexts(n_gpus, &g, image_split);
                let proj = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap().0.unwrap();

                // session-driven Landweber-style loop
                let mut sess = ReconSession::new(&ctx, &g).unwrap();
                let b = TrackedProjections::new(proj.clone());
                let mut x = TrackedVolume::new(Volume::zeros_like(&g));
                // uncached reference loop: identical math through the
                // stateless executor
                let mut x_ref = Volume::zeros_like(&g);

                for it in 0..3 {
                    let ax = sess.forward(&x).unwrap();
                    let (upd, norm) = sess.backward_residual(&b, &ax).unwrap();
                    let bp_stats = sess.last.as_ref().unwrap().residency;
                    drop(ax);
                    x.write().add_scaled(&upd, 1e-3);

                    let (ax_ref, _) = ctx.forward(&g, Some(&x_ref), ExecMode::Full).unwrap();
                    let mut r_ref = proj.clone();
                    r_ref.add_scaled(&ax_ref.unwrap(), -1.0);
                    assert!((norm - r_ref.norm2()).abs() <= 1e-9 * (1.0 + norm));
                    let (upd_ref, _) = ctx.backward(&g, Some(&r_ref), ExecMode::Full).unwrap();
                    x_ref.add_scaled(&upd_ref.unwrap(), 1e-3);

                    assert_eq!(
                        x.get().data, x_ref.data,
                        "gpus={n_gpus} split={image_split} iter={it}: \
                         session must be bit-identical to the uncached executor"
                    );

                    if it >= 1 && !image_split {
                        // 2nd+ iterations: b is resident everywhere and each
                        // device holds its own share of Ax — the only
                        // staging left is cross-device Ax chunks, which is
                        // fresh data, not redundancy.
                        let bp_plan = crate::coordinator::splitter::plan_backward(
                            &g,
                            ctx.n_gpus,
                            ctx.spec.mem_bytes,
                            &ctx.split,
                        )
                        .unwrap();
                        let fp_plan = crate::coordinator::splitter::plan_forward(
                            &g,
                            ctx.n_gpus,
                            ctx.spec.mem_bytes,
                            &ctx.split,
                        )
                        .unwrap();
                        let shares = fp_plan.chunk_shares(ctx.n_gpus);
                        let mut expected_misses = 0u64;
                        for &(c0, c1) in &shares {
                            let (a_lo, a_hi) = if c1 > c0 {
                                (fp_plan.angle_chunks[c0].a0, fp_plan.angle_chunks[c1 - 1].a1)
                            } else {
                                (0, 0)
                            };
                            for ch in &bp_plan.angle_chunks {
                                if !(ch.a0 >= a_lo && ch.a1 <= a_hi) {
                                    expected_misses += 1; // cross-device Ax chunk
                                }
                            }
                        }
                        assert_eq!(
                            bp_stats.misses, expected_misses,
                            "gpus={n_gpus} iter={it}: only cross-device Ax chunks may stage"
                        );
                        assert!(bp_stats.hits > 0, "gpus={n_gpus} iter={it}: hits expected");
                        if n_gpus == 1 {
                            assert_eq!(
                                bp_stats.misses, 0,
                                "1 GPU: 2nd+ iterations must stage no projections at all"
                            );
                        }
                    }
                }
                // Cached-vs-uncached simulated time. At this tiny test
                // geometry the single BP chunk spans all angles, so with
                // >1 GPU no FP output share covers it and the residual
                // scheme's steady state matches (not beats) the uncached
                // traffic; the guaranteed win is the 1-GPU case, where
                // 2nd+ iterations stage nothing at all. (At paper-scale
                // angle counts the BP chunks mostly fall inside one FP
                // share — see `bench::coordinator`'s residency entries.)
                if !image_split {
                    let mut un = ReconSession::new(&ctx, &g).unwrap().without_residency();
                    let ub = TrackedProjections::new(proj.clone());
                    let mut ux = TrackedVolume::new(Volume::zeros_like(&g));
                    for _ in 0..3 {
                        let ax = un.forward(&ux).unwrap();
                        let (upd, _) = un.backward_residual(&ub, &ax).unwrap();
                        ux.write().add_scaled(&upd, 1e-3);
                    }
                    assert_eq!(un.residency, ResidencyStats::default());
                    if n_gpus == 1 {
                        assert!(
                            sess.sim_time_s < un.sim_time_s,
                            "1 GPU: cached {} !< uncached {}",
                            sess.sim_time_s,
                            un.sim_time_s
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn image_split_budget_is_zero_and_everything_misses() {
        let g = Geometry::cone_beam(16, 12);
        let ctx = contexts(2, &g, true);
        let mut sess = ReconSession::new(&ctx, &g).unwrap();
        // the split regime leaves less than one BP chunk of slack beyond
        // the working set, so nothing is ever cacheable
        let bp_chunk_bytes =
            SplitConfig::default().bp_chunk.min(g.n_angles()) as u64 * g.single_proj_bytes();
        assert!(
            sess.residency_budget() < bp_chunk_bytes,
            "budget {} should not fit a BP chunk ({bp_chunk_bytes})",
            sess.residency_budget()
        );
        let x = TrackedVolume::new(phantom::shepp_logan(16));
        let p = sess.forward(&x).unwrap();
        assert_eq!(sess.last.as_ref().unwrap().residency.hits, 0);
        assert!(sess.last.as_ref().unwrap().residency.misses > 0);
        let _ = sess.backward(&p).unwrap();
        let bp = sess.last.as_ref().unwrap().residency;
        assert_eq!(bp.hits, 0, "no budget ⇒ no hits, but still correct");
        assert!(bp.misses > 0);
    }

    #[test]
    fn session_peak_memory_never_exceeds_capacity() {
        // resident buffers + working set must respect the ledger: the
        // conservative budget guarantees no simulated OOM and a peak
        // within capacity even with the cache as full as it gets.
        let g = Geometry::cone_beam(16, 12);
        for image_split in [false, true] {
            let ctx = contexts(2, &g, image_split);
            let mut sess = ReconSession::new(&ctx, &g).unwrap();
            let b = TrackedProjections::new(
                ctx.forward(&g, Some(&phantom::shepp_logan(16)), ExecMode::Full)
                    .unwrap()
                    .0
                    .unwrap(),
            );
            let mut x = TrackedVolume::new(Volume::zeros_like(&g));
            for _ in 0..3 {
                let ax = sess.forward(&x).unwrap();
                let (upd, _) = sess.backward_residual(&b, &ax).unwrap();
                x.write().add_scaled(&upd, 1e-3);
            }
            assert!(
                sess.peak_device_bytes <= ctx.spec.mem_bytes,
                "split={image_split}: peak {} > capacity {}",
                sess.peak_device_bytes,
                ctx.spec.mem_bytes
            );
        }
    }

    #[test]
    fn sparse_shard_cache_builds_once_then_hits() {
        let g = Geometry::cone_beam(12, 6);
        let cache = SparseShardCache::new();
        let m1 = cache.get_or_build(&g, 2);
        let s = cache.stats();
        assert_eq!((s.builds, s.hits), (1, 0));
        assert_eq!(s.resident_bytes, m1.bytes());
        let m2 = cache.get_or_build(&g, 2);
        let s = cache.stats();
        assert_eq!((s.builds, s.hits), (1, 1), "second launch must reuse the shard");
        assert!(std::sync::Arc::ptr_eq(&m1, &m2));
        // a different sub-geometry is a different shard
        let _ = cache.get_or_build(&g.slab_geometry(0, 6), 2);
        assert_eq!(cache.stats().builds, 2);
    }

    #[test]
    fn sparse_shard_cache_lru_evicts_under_tight_budget() {
        let g = Geometry::cone_beam(12, 6);
        let a = g.slab_geometry(0, 6);
        let b = g.slab_geometry(6, 12);
        let one = SparseShardCache::new().get_or_build(&a, 1).bytes();
        // budget fits one shard, not two
        let cache = SparseShardCache::with_budget(one + one / 2);
        let _ = cache.get_or_build(&a, 1);
        let _ = cache.get_or_build(&b, 1);
        let s = cache.stats();
        assert_eq!(s.builds, 2);
        assert_eq!(s.evictions, 1, "second shard must evict the first");
        assert!(s.resident_bytes <= one + one / 2);
        // shard `a` was evicted: asking again rebuilds
        let _ = cache.get_or_build(&a, 1);
        assert_eq!(cache.stats().builds, 3);
    }

    #[test]
    fn sparse_shard_cache_oversized_shard_is_returned_but_not_retained() {
        let g = Geometry::cone_beam(12, 6);
        let cache = SparseShardCache::with_budget(16);
        let m = cache.get_or_build(&g, 1);
        assert!(m.nnz() > 0, "the launch still gets a usable shard");
        let s = cache.stats();
        assert_eq!(s.builds, 1);
        assert_eq!(s.resident_bytes, 0, "oversized shard must not be retained");
        let _ = cache.get_or_build(&g, 1);
        assert_eq!(cache.stats().builds, 2, "not retained ⇒ rebuilt");
    }

    #[test]
    fn sparse_sim_warmth_is_per_op_and_per_plan() {
        let cache = SparseShardCache::new();
        assert!(!cache.sim_op_warm(OpKind::Fp, 1), "first FP sim op is cold");
        assert!(cache.sim_op_warm(OpKind::Fp, 1), "second is warm");
        assert!(!cache.sim_op_warm(OpKind::Bp, 1), "BP shards are separate");
        assert!(!cache.sim_op_warm(OpKind::Fp, 2), "a replanned FP is cold again");
        assert!(cache.sim_op_warm(OpKind::Bp, 1));
    }

    #[test]
    fn forward_output_can_be_mutated_and_backprojected() {
        // MLEM/OS-SART pattern: mutate the forward output in place, then
        // backproject it — the epoch bump must force a (correct) restage.
        let g = Geometry::cone_beam(14, 8);
        let ctx = MultiGpu::gtx1080ti(1);
        let v = phantom::cube(14, 0.5, 1.0);
        let mut sess = ReconSession::new(&ctx, &g).unwrap();
        let x = TrackedVolume::new(v);
        let mut ratio = sess.forward(&x).unwrap();
        for r in &mut ratio.write().data {
            *r *= 0.5;
        }
        let got = sess.backward(&ratio).unwrap();
        let expect = ctx.backward(&g, Some(ratio.get()), ExecMode::Full).unwrap().0.unwrap();
        assert_eq!(got.data, expect.data);
    }
}
