//! Quickstart: reconstruct a 32³ Shepp–Logan phantom with OS-SART on a
//! 2-(simulated-)GPU node — the smallest end-to-end tour of the public
//! API: geometry → phantom → forward projection → reconstruction →
//! quality metrics.
//!
//! Run with: `cargo run --release --example quickstart`

use tigre::algorithms::{self, ReconOpts};
use tigre::coordinator::{ExecMode, MultiGpu};
use tigre::geometry::Geometry;
use tigre::metrics;
use tigre::phantom;

fn main() -> anyhow::Result<()> {
    // 1. a cone-beam scan geometry: 32³ voxels, 32² detector, 48 angles
    let g = Geometry::cone_beam(32, 48);
    g.validate().map_err(|e| anyhow::anyhow!(e))?;

    // 2. ground truth + simulated measurement
    let truth = phantom::shepp_logan(32);
    let node = MultiGpu::gtx1080ti(2); // 2 simulated GTX 1080 Ti
    let (proj, fp_stats) = node.forward(&g, Some(&truth), ExecMode::Full)?;
    let proj = proj.unwrap();
    println!(
        "forward projection: {} angles, simulated {:.3}s on {} GPUs",
        g.n_angles(),
        fp_stats.makespan_s,
        node.n_gpus
    );

    // 3. iterative reconstruction
    let result = algorithms::os_sart(
        &node,
        &g,
        &proj,
        8,
        &ReconOpts { iterations: 10, lambda: 0.9, ..Default::default() },
    )?;

    // 4. report
    println!("OS-SART (subset 8, 10 iterations):");
    println!("  RMSE vs truth : {:.5}", metrics::rmse(&truth, &result.volume));
    println!("  PSNR vs truth : {:.2} dB", metrics::psnr(&truth, &result.volume));
    println!("  simulated time: {:.3}s (GTX 1080 Ti ×2 estimate)", result.sim_time_s);
    println!(
        "  residual      : {:.3e} → {:.3e}",
        result.residuals.first().unwrap(),
        result.residuals.last().unwrap()
    );
    tigre::io::save_slice_pgm(
        std::path::Path::new("results/quickstart_slice.pgm"),
        &result.volume,
        16,
        None,
    )?;
    println!("  central slice : results/quickstart_slice.pgm");
    Ok(())
}
