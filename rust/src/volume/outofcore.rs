//! Out-of-core backing stores: stream volumes and projection sets from
//! disk so reconstructions can exceed host RAM (PR 5).
//!
//! The paper makes device memory a non-limit by slab/chunk-splitting the
//! problem between host RAM and the GPUs; this module applies the same
//! move one level up the memory hierarchy (disk → host → device), the
//! staging strategy of Petascale XCT (Hidayetoğlu et al., 2020) and
//! Sparse-Matrix HPC Tomography (Marchesini et al., 2020):
//!
//! * [`SlabStore`] — a raw-f32 file addressed in contiguous *planes*
//!   (axial z-slices of a volume, per-angle projections of a set — both
//!   contiguous by the crate's layout invariants), cached in slab-granular
//!   units under a bounded host-RAM budget with LRU eviction and
//!   dirty-slab writeback. This mirrors `coordinator::residency`'s
//!   device-side design one tier up: budget-bounded, recency-evicted,
//!   with the cache never changing what a reader observes.
//! * [`OocVolume`] / [`OocProjections`] — typed wrappers giving the store
//!   the shapes and the sidecar format of [`crate::io::save_volume`]
//!   (raw little-endian f32 + a `.json` shape sidecar), so any OOC file
//!   is also loadable by `io::load_volume` and numpy.
//!
//! All cache state lives behind a `Mutex`, so every method takes `&self`:
//! the pipelined executor's loader lanes prefetch slabs from worker
//! threads while the host thread owns the store.
//!
//! Determinism: the store is a byte-transparent window onto the file —
//! a `load` observes exactly the last `store`d bytes for every plane,
//! whatever the cache did in between (eviction, writeback, bypass). The
//! executors therefore produce bit-identical results streaming from a
//! store or borrowing host-resident arrays; `coordinator::pipeline`'s
//! parity tests pin that.

// The slab cache is keyed for O(1) lookups; every iteration that could
// leak map order (flush writeback, LRU scan) sorts or tie-breaks on a
// unique clock first (see rust/clippy.toml).
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;
use std::fmt;
use std::fs;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::simgpu::fault::{FaultPlan, FaultScope, MAX_LAUNCH_RETRIES};
use crate::util::json::Json;
use crate::volume::{ProjectionSet, Volume};

/// Bounded retry budget for disk reads and writebacks, shared with the
/// launch-retry budget so "how many times do we re-try a flaky unit" is
/// one number across the whole fault-tolerance layer (ISSUE 7).
pub const MAX_DISK_ATTEMPTS: usize = MAX_LAUNCH_RETRIES;

/// Base backoff between disk retries; doubles per attempt. Short: this
/// covers transient EINTR-class hiccups and injected test faults, not
/// spun-down media.
const DISK_RETRY_BACKOFF_US: u64 = 50;

/// A disk read or writeback that kept failing past
/// [`MAX_DISK_ATTEMPTS`]. Typed (not a bare `anyhow!` string) so the
/// recovery layer and the tests can tell an exhausted retry budget from
/// shape/usage errors; `op` is `"read"` or `"write"`.
#[derive(Debug)]
pub struct OocIoError {
    /// Backing file the operation targeted.
    pub path: PathBuf,
    /// `"read"` or `"write"`.
    pub op: &'static str,
    /// How many attempts were made before giving up.
    pub attempts: usize,
    /// The final I/O error.
    pub source: std::io::Error,
}

impl fmt::Display for OocIoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: disk {} failed after {} attempts",
            self.path.display(),
            self.op,
            self.attempts
        )
    }
}

impl std::error::Error for OocIoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(&self.source)
    }
}

/// Cumulative accounting of one store's traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Plane-range requests served entirely from cached slabs.
    pub hits: u64,
    /// Slab reads that went to disk (cache miss or bypass).
    pub loads: u64,
    /// Slabs evicted by the budget-driven LRU.
    pub evictions: u64,
    /// Dirty slabs written back (evictions + flushes + write-through).
    pub writebacks: u64,
    /// Bytes read from disk.
    pub bytes_read: u64,
    /// Bytes written to disk.
    pub bytes_written: u64,
}

#[derive(Debug)]
struct CachedSlab {
    data: Vec<f32>,
    dirty: bool,
    last_use: u64,
}

#[derive(Debug)]
struct Inner {
    file: fs::File,
    /// Slab index → cached slab.
    cache: HashMap<usize, CachedSlab>,
    used_bytes: u64,
    clock: u64,
    stats: StoreStats,
    /// Reused encode/decode byte buffer — file I/O runs under the store
    /// mutex, so one buffer serves every request without per-slab
    /// allocation on the streaming hot path.
    io_buf: Vec<u8>,
    /// Optional fault injector (ISSUE 7): `read_file` consults it for
    /// injected disk failures before touching the real file, so the
    /// retry/typed-error path is testable without flaky media.
    fault: Option<Arc<FaultPlan>>,
}

/// A disk-backed array of `n_planes` contiguous planes of `plane_elems`
/// f32 values each, cached in slabs of `slab_planes` planes under
/// `budget_bytes` of host RAM. See the module docs.
#[derive(Debug)]
pub struct SlabStore {
    path: PathBuf,
    plane_elems: usize,
    n_planes: usize,
    slab_planes: usize,
    budget_bytes: u64,
    /// False when the backing file could only be opened read-only
    /// (write-protected measurement data): loads stream normally,
    /// stores are a typed error instead of a deferred writeback panic.
    writable: bool,
    inner: Mutex<Inner>,
}

impl SlabStore {
    /// Create a zero-filled store file of `n_planes × plane_elems` f32s.
    /// (`set_len` extends sparsely with zeros — creating a store bigger
    /// than host RAM costs no RAM and no write traffic.)
    fn create(
        path: &Path,
        plane_elems: usize,
        n_planes: usize,
        slab_planes: usize,
        budget_bytes: u64,
    ) -> anyhow::Result<SlabStore> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let file = fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        file.set_len((plane_elems * n_planes) as u64 * 4)?;
        Self::from_file(path, file, true, plane_elems, n_planes, slab_planes, budget_bytes)
    }

    /// Open an existing store file, verifying its length matches the
    /// shape. Falls back to a read-only open for write-protected input
    /// files (measured projections on read-only media): loads work,
    /// stores become a typed error.
    fn open(
        path: &Path,
        plane_elems: usize,
        n_planes: usize,
        slab_planes: usize,
        budget_bytes: u64,
    ) -> anyhow::Result<SlabStore> {
        let (file, writable) = match fs::OpenOptions::new().read(true).write(true).open(path) {
            Ok(f) => (f, true),
            Err(_) => (fs::OpenOptions::new().read(true).open(path)?, false),
        };
        let want = (plane_elems * n_planes) as u64 * 4;
        let got = file.metadata()?.len();
        anyhow::ensure!(
            got == want,
            "{}: raw size {got} B does not match sidecar shape ({want} B expected)",
            path.display()
        );
        Self::from_file(path, file, writable, plane_elems, n_planes, slab_planes, budget_bytes)
    }

    #[allow(clippy::too_many_arguments)]
    fn from_file(
        path: &Path,
        file: fs::File,
        writable: bool,
        plane_elems: usize,
        n_planes: usize,
        slab_planes: usize,
        budget_bytes: u64,
    ) -> anyhow::Result<SlabStore> {
        anyhow::ensure!(plane_elems > 0 && n_planes > 0, "empty store shape");
        anyhow::ensure!(slab_planes > 0, "slab granularity must be > 0");
        Ok(SlabStore {
            path: path.to_path_buf(),
            plane_elems,
            n_planes,
            slab_planes: slab_planes.min(n_planes),
            budget_bytes,
            writable,
            inner: Mutex::new(Inner {
                file,
                cache: HashMap::new(),
                used_bytes: 0,
                clock: 0,
                stats: StoreStats::default(),
                io_buf: Vec::new(),
                fault: None,
            }),
        })
    }

    /// Path of the backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Host-RAM budget the cached slabs must fit in.
    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Cache granularity, planes per slab.
    pub fn slab_planes(&self) -> usize {
        self.slab_planes
    }

    /// Total stored bytes (the file size).
    pub fn total_bytes(&self) -> u64 {
        (self.plane_elems * self.n_planes) as u64 * 4
    }

    /// Bytes currently cached in host RAM (always ≤ the budget).
    pub fn resident_bytes(&self) -> u64 {
        self.lock().used_bytes
    }

    /// Cumulative traffic statistics.
    pub fn stats(&self) -> StoreStats {
        self.lock().stats
    }

    /// Attach a fault injector: subsequent disk reads consult it (in the
    /// `Real` scope) for injected failures before touching the file.
    pub fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        self.lock().fault = Some(plan);
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // a poisoned store mutex means a loader/worker thread died mid-
        // operation; the cache map itself is never left inconsistent
        // (every section restores invariants before any I/O `?`), so
        // recover the guard and keep serving
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Planes covered by slab `idx`: `[p0, p1)`.
    fn slab_range(&self, idx: usize) -> (usize, usize) {
        let p0 = idx * self.slab_planes;
        (p0, (p0 + self.slab_planes).min(self.n_planes))
    }

    fn slab_bytes(&self, idx: usize) -> u64 {
        let (p0, p1) = self.slab_range(idx);
        ((p1 - p0) * self.plane_elems) as u64 * 4
    }

    // ---- raw file I/O (always under the inner lock) ---------------------

    fn read_file(&self, inner: &mut Inner, p0: usize, dst: &mut [f32]) -> anyhow::Result<()> {
        let off = (p0 * self.plane_elems) as u64 * 4;
        let n = dst.len() * 4;
        // reuse the store's I/O buffer; zero-fill only on growth (the
        // read overwrites every byte it hands to the decoder)
        let mut bytes = std::mem::take(&mut inner.io_buf);
        if bytes.len() < n {
            bytes.resize(n, 0);
        }
        // one disk-op ordinal per logical read, however many retries it
        // takes — the injector's site addresses the read, not an attempt
        let mut injected = inner
            .fault
            .as_ref()
            .map_or(0, |f| f.disk_fault(FaultScope::Real));
        let mut last_err: Option<std::io::Error> = None;
        for attempt in 1..=MAX_DISK_ATTEMPTS {
            if attempt > 1 {
                std::thread::sleep(std::time::Duration::from_micros(
                    DISK_RETRY_BACKOFF_US << (attempt - 2),
                ));
            }
            if injected > 0 {
                injected -= 1;
                last_err = Some(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "injected disk fault",
                ));
                continue;
            }
            // seek inside the loop: a short read can move the cursor
            let res = inner
                .file
                .seek(SeekFrom::Start(off))
                .and_then(|_| inner.file.read_exact(&mut bytes[..n]));
            match res {
                Ok(()) => {
                    for (d, b) in dst.iter_mut().zip(bytes[..n].chunks_exact(4)) {
                        *d = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                    }
                    inner.io_buf = bytes;
                    inner.stats.loads += 1;
                    inner.stats.bytes_read += n as u64;
                    return Ok(());
                }
                Err(e) => last_err = Some(e),
            }
        }
        inner.io_buf = bytes;
        Err(OocIoError {
            path: self.path.clone(),
            op: "read",
            attempts: MAX_DISK_ATTEMPTS,
            source: last_err.unwrap_or_else(|| {
                std::io::Error::new(std::io::ErrorKind::Other, "no attempt recorded")
            }),
        }
        .into())
    }

    fn write_file(&self, inner: &mut Inner, p0: usize, src: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.writable,
            "{}: store was opened read-only (write-protected file); writes are not possible",
            self.path.display()
        );
        let off = (p0 * self.plane_elems) as u64 * 4;
        let mut bytes = std::mem::take(&mut inner.io_buf);
        bytes.clear();
        for v in src {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        // same bounded-backoff discipline as `read_file`: a transient
        // write hiccup must not lose a dirty slab mid-eviction (ISSUE 8)
        let mut injected = inner
            .fault
            .as_ref()
            .map_or(0, |f| f.disk_fault(FaultScope::Real));
        let mut last_err: Option<std::io::Error> = None;
        for attempt in 1..=MAX_DISK_ATTEMPTS {
            if attempt > 1 {
                std::thread::sleep(std::time::Duration::from_micros(
                    DISK_RETRY_BACKOFF_US << (attempt - 2),
                ));
            }
            if injected > 0 {
                injected -= 1;
                last_err = Some(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "injected disk fault",
                ));
                continue;
            }
            // seek inside the loop: a short write can move the cursor
            let res = inner
                .file
                .seek(SeekFrom::Start(off))
                .and_then(|_| inner.file.write_all(&bytes));
            match res {
                Ok(()) => {
                    let n = bytes.len() as u64;
                    inner.io_buf = bytes;
                    inner.stats.writebacks += 1;
                    inner.stats.bytes_written += n;
                    return Ok(());
                }
                Err(e) => last_err = Some(e),
            }
        }
        inner.io_buf = bytes;
        Err(OocIoError {
            path: self.path.clone(),
            op: "write",
            attempts: MAX_DISK_ATTEMPTS,
            source: last_err.unwrap_or_else(|| {
                std::io::Error::new(std::io::ErrorKind::Other, "no attempt recorded")
            }),
        }
        .into())
    }

    // ---- cache machinery ------------------------------------------------

    /// Evict LRU slabs (writing dirty ones back) until `need` more bytes
    /// fit the budget.
    fn evict_to_fit(&self, inner: &mut Inner, need: u64) -> anyhow::Result<()> {
        while inner.used_bytes + need > self.budget_bytes {
            let Some((&lru, _)) = inner.cache.iter().min_by_key(|(_, s)| s.last_use) else {
                break;
            };
            let Some(slab) = inner.cache.remove(&lru) else { break };
            inner.used_bytes -= (slab.data.len() * 4) as u64;
            if slab.dirty {
                let (p0, _) = self.slab_range(lru);
                if let Err(e) = self.write_file(inner, p0, &slab.data) {
                    // writeback failed past the retry budget: reinsert
                    // the dirty slab so its bytes are not lost — the
                    // caller sees the typed error, the cache stays whole
                    inner.used_bytes += (slab.data.len() * 4) as u64;
                    inner.cache.insert(lru, slab);
                    return Err(e);
                }
            }
            inner.stats.evictions += 1;
        }
        Ok(())
    }

    /// Ensure slab `idx` is cached (reading it from disk on a miss),
    /// bumping its LRU clock. Precondition: `slab_bytes(idx) ≤ budget`.
    fn ensure_cached(&self, inner: &mut Inner, idx: usize) -> anyhow::Result<()> {
        inner.clock += 1;
        let clock = inner.clock;
        if let Some(slab) = inner.cache.get_mut(&idx) {
            slab.last_use = clock;
            return Ok(());
        }
        let bytes = self.slab_bytes(idx);
        self.evict_to_fit(inner, bytes)?;
        let (p0, p1) = self.slab_range(idx);
        let mut data = vec![0.0f32; (p1 - p0) * self.plane_elems];
        self.read_file(inner, p0, &mut data)?;
        inner.cache.insert(idx, CachedSlab { data, dirty: false, last_use: clock });
        inner.used_bytes += bytes;
        Ok(())
    }

    // ---- public plane-range API ----------------------------------------

    /// Copy planes `[p0, p1)` into `dst` (`dst.len()` must equal the
    /// range's element count). Served from cached slabs where possible;
    /// slabs larger than the whole budget bypass the cache (direct read).
    pub fn load_planes_into(&self, p0: usize, p1: usize, dst: &mut [f32]) -> anyhow::Result<()> {
        assert!(p0 < p1 && p1 <= self.n_planes, "bad plane range [{p0},{p1})");
        assert_eq!(dst.len(), (p1 - p0) * self.plane_elems, "load dst length mismatch");
        let mut guard = self.lock();
        let inner = &mut *guard;
        let mut all_cached = true;
        let mut idx = p0 / self.slab_planes;
        loop {
            let (s0, s1) = self.slab_range(idx);
            if s0 >= p1 {
                break;
            }
            let lo = p0.max(s0);
            let hi = p1.min(s1);
            let dst_off = (lo - p0) * self.plane_elems;
            let len = (hi - lo) * self.plane_elems;
            if self.slab_bytes(idx) > self.budget_bytes {
                // stream-only slab: the cache can never hold it
                all_cached = false;
                self.read_file(inner, lo, &mut dst[dst_off..dst_off + len])?;
            } else {
                if !inner.cache.contains_key(&idx) {
                    all_cached = false;
                }
                self.ensure_cached(inner, idx)?;
                let slab = &inner.cache[&idx];
                let src_off = (lo - s0) * self.plane_elems;
                dst[dst_off..dst_off + len]
                    .copy_from_slice(&slab.data[src_off..src_off + len]);
            }
            idx += 1;
        }
        if all_cached {
            inner.stats.hits += 1;
        }
        Ok(())
    }

    /// Write planes `[p0, p1)` from `src`. Writes land in the cache as
    /// dirty slabs (written back on eviction or [`SlabStore::flush`]);
    /// whole-slab writes skip the read-miss, and slabs larger than the
    /// budget write through directly.
    pub fn store_planes(&self, p0: usize, p1: usize, src: &[f32]) -> anyhow::Result<()> {
        assert!(p0 < p1 && p1 <= self.n_planes, "bad plane range [{p0},{p1})");
        assert_eq!(src.len(), (p1 - p0) * self.plane_elems, "store src length mismatch");
        // fail fast instead of accepting dirty slabs a read-only file
        // could never write back at eviction/flush time
        anyhow::ensure!(
            self.writable,
            "{}: store was opened read-only (write-protected file); writes are not possible",
            self.path.display()
        );
        let mut guard = self.lock();
        let inner = &mut *guard;
        let mut idx = p0 / self.slab_planes;
        loop {
            let (s0, s1) = self.slab_range(idx);
            if s0 >= p1 {
                break;
            }
            let lo = p0.max(s0);
            let hi = p1.min(s1);
            let src_off = (lo - p0) * self.plane_elems;
            let len = (hi - lo) * self.plane_elems;
            if self.slab_bytes(idx) > self.budget_bytes {
                // write-through for stream-only slabs; drop any cached
                // copy first so it cannot shadow the new bytes
                if let Some(old) = inner.cache.remove(&idx) {
                    inner.used_bytes -= (old.data.len() * 4) as u64;
                }
                self.write_file(inner, lo, &src[src_off..src_off + len])?;
            } else {
                let fresh_full_slab =
                    lo == s0 && hi == s1 && !inner.cache.contains_key(&idx);
                if fresh_full_slab {
                    // full-slab overwrite: no need to read the old bytes
                    inner.clock += 1;
                    let clock = inner.clock;
                    let bytes = self.slab_bytes(idx);
                    self.evict_to_fit(inner, bytes)?;
                    inner.cache.insert(
                        idx,
                        CachedSlab {
                            data: src[src_off..src_off + len].to_vec(),
                            dirty: true,
                            last_use: clock,
                        },
                    );
                    inner.used_bytes += bytes;
                } else {
                    self.ensure_cached(inner, idx)?;
                    let Some(slab) = inner.cache.get_mut(&idx) else {
                        return Err(OocIoError {
                            path: self.path.clone(),
                            op: "write",
                            attempts: 0,
                            source: std::io::Error::new(
                                std::io::ErrorKind::Other,
                                "slab vanished from the cache after ensure_cached",
                            ),
                        }
                        .into());
                    };
                    let off = (lo - s0) * self.plane_elems;
                    slab.data[off..off + len].copy_from_slice(&src[src_off..src_off + len]);
                    slab.dirty = true;
                }
            }
            idx += 1;
        }
        Ok(())
    }

    /// Write every dirty cached slab back to disk (entries stay cached,
    /// clean). Call before handing the file to an outside reader.
    pub fn flush(&self) -> anyhow::Result<()> {
        let mut guard = self.lock();
        let inner = &mut *guard;
        let mut dirty: Vec<usize> = inner
            .cache
            .iter()
            .filter(|(_, s)| s.dirty)
            .map(|(&i, _)| i)
            .collect();
        // ascending slab order: writeback sequence (and therefore the
        // fault-injection schedule) must not depend on HashMap iteration
        dirty.sort_unstable();
        let wrote = !dirty.is_empty();
        for idx in dirty {
            let (p0, _) = self.slab_range(idx);
            let Some(slab) = inner.cache.get_mut(&idx) else { continue };
            let data = std::mem::take(&mut slab.data);
            let res = self.write_file(inner, p0, &data);
            // restore the slab's bytes before surfacing any error, so a
            // failed writeback never leaves an empty-but-dirty slab
            if let Some(slab) = inner.cache.get_mut(&idx) {
                slab.data = data;
                res?;
                slab.dirty = false;
            } else {
                res?;
            }
        }
        if wrote {
            // flush() is the durability point checkpoints and hand-offs
            // rely on: force the written-back slabs to stable storage
            inner.file.sync_all()?;
        }
        Ok(())
    }
}

impl Drop for SlabStore {
    fn drop(&mut self) {
        // best-effort writeback so a dropped store never silently loses
        // dirty slabs; explicit flush() is still the checked path
        let _ = self.flush();
    }
}

// ---------------------------------------------------------------------------
// typed wrappers
// ---------------------------------------------------------------------------

/// Write the `io::save_volume`-format sidecar for a raw file of shape
/// `(nx, ny, nz)` without materializing any data.
fn write_sidecar(path: &Path, nx: usize, ny: usize, nz: usize) -> anyhow::Result<()> {
    let meta = Json::obj(vec![
        ("dtype", Json::str("f32le")),
        ("nx", Json::num(nx as f64)),
        ("ny", Json::num(ny as f64)),
        ("nz", Json::num(nz as f64)),
        ("order", Json::str("z-slowest (z,y,x)")),
    ]);
    write_json_atomic(&path.with_extension("json"), &meta.pretty())
}

/// Durable atomic small-file write: temp file in the same directory,
/// fsync, rename over the destination. A crash mid-write leaves either
/// the old file or the new one, never a torn sidecar/manifest.
pub(crate) fn write_json_atomic(dest: &Path, text: &str) -> anyhow::Result<()> {
    let tmp = dest.with_extension("json.tmp");
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(text.as_bytes())?;
        f.sync_all()?;
    }
    fs::rename(&tmp, dest)?;
    Ok(())
}

/// Read a sidecar's `(nx, ny, nz)`.
fn read_sidecar(path: &Path) -> anyhow::Result<(usize, usize, usize)> {
    let text = fs::read_to_string(path.with_extension("json"))?;
    let meta = Json::parse(&text)?;
    let dim = |k: &str| {
        meta.get(k)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("{}: sidecar missing '{k}'", path.display()))
    };
    Ok((dim("nx")?, dim("ny")?, dim("nz")?))
}

/// An out-of-core [`Volume`]: raw-f32 file + JSON sidecar (exactly
/// [`crate::io::save_volume`]'s format), accessed in z-slabs through a
/// budgeted [`SlabStore`]. Layout is z-slowest, so a z-slab is one
/// contiguous file range — the same invariant that makes device staging
/// single-copy makes disk staging single-`read`.
#[derive(Debug)]
pub struct OocVolume {
    store: SlabStore,
    /// Voxels along x.
    pub nx: usize,
    /// Voxels along y.
    pub ny: usize,
    /// Voxels along z.
    pub nz: usize,
}

impl OocVolume {
    /// Create a zero-filled OOC volume (sparse file — no RAM, no writes).
    pub fn create(
        path: &Path,
        nx: usize,
        ny: usize,
        nz: usize,
        slab_nz: usize,
        budget_bytes: u64,
    ) -> anyhow::Result<OocVolume> {
        let store = SlabStore::create(path, nx * ny, nz, slab_nz, budget_bytes)?;
        write_sidecar(path, nx, ny, nz)?;
        Ok(OocVolume { store, nx, ny, nz })
    }

    /// Open an existing raw+sidecar volume (e.g. one written by
    /// [`crate::io::save_volume`]).
    pub fn open(path: &Path, slab_nz: usize, budget_bytes: u64) -> anyhow::Result<OocVolume> {
        let (nx, ny, nz) = read_sidecar(path)?;
        let store = SlabStore::open(path, nx * ny, nz, slab_nz, budget_bytes)?;
        Ok(OocVolume { store, nx, ny, nz })
    }

    /// Spill an in-RAM volume to disk and open it as a store.
    pub fn from_volume(
        path: &Path,
        v: &Volume,
        slab_nz: usize,
        budget_bytes: u64,
    ) -> anyhow::Result<OocVolume> {
        crate::io::save_volume(path, v)?;
        Self::open(path, slab_nz, budget_bytes)
    }

    /// Materialize the whole volume in RAM **through the store cache**
    /// (dirty slabs are observed without a flush; cached slabs cost no
    /// disk I/O). This is the executors' materialization path for
    /// angle-split plans, whose precondition — the volume fits the host
    /// budget — means repeat calls in an iteration loop are served from
    /// the cache instead of re-reading the file.
    pub fn read_volume(&self) -> anyhow::Result<Volume> {
        let mut v = Volume::zeros(self.nx, self.ny, self.nz);
        let step = self.store.slab_planes();
        let mut z0 = 0;
        while z0 < self.nz {
            let z1 = (z0 + step).min(self.nz);
            let plane = self.nx * self.ny;
            self.load_slab_into(z0, z1, &mut v.data[z0 * plane..z1 * plane])?;
            z0 = z1;
        }
        Ok(v)
    }

    /// Materialize the whole volume in RAM by flushing and re-reading
    /// the raw file (the outside-reader view; parity tests). Prefer
    /// [`OocVolume::read_volume`] on hot paths — it serves from the
    /// cache and needs no flush.
    pub fn to_volume(&self) -> anyhow::Result<Volume> {
        self.store.flush()?;
        crate::io::load_volume(self.store.path())
    }

    /// `(nx, ny, nz)`.
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.nx, self.ny, self.nz)
    }

    /// Logical size in bytes (the file size).
    pub fn bytes(&self) -> u64 {
        self.store.total_bytes()
    }

    /// Host-RAM cache budget of the backing store.
    pub fn budget_bytes(&self) -> u64 {
        self.store.budget_bytes()
    }

    /// Cumulative traffic statistics of the backing store.
    pub fn stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Path of the backing file.
    pub fn path(&self) -> &Path {
        self.store.path()
    }

    /// Write every dirty cached slab back to disk.
    pub fn flush(&self) -> anyhow::Result<()> {
        self.store.flush()
    }

    /// Attach a fault injector to the backing store (see
    /// [`SlabStore::set_fault_plan`]).
    pub fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        self.store.set_fault_plan(plan);
    }

    /// Copy the z-slab `[z0, z1)` into `dst` (length `(z1−z0)·nx·ny`).
    pub fn load_slab_into(&self, z0: usize, z1: usize, dst: &mut [f32]) -> anyhow::Result<()> {
        self.store.load_planes_into(z0, z1, dst)
    }

    /// Write `src` (a whole number of planes) back at slice offset `z0`.
    pub fn store_slab(&self, z0: usize, src: &[f32]) -> anyhow::Result<()> {
        let plane = self.nx * self.ny;
        assert_eq!(src.len() % plane, 0, "store_slab: partial plane");
        self.store.store_planes(z0, z0 + src.len() / plane, src)
    }

    /// Streamed `x ← x + s·other`: read-modify-write one store slab at a
    /// time, so the update of a bigger-than-budget volume never holds
    /// more than one slab (plus `other`'s borrow) in RAM. Elementwise
    /// order matches [`Volume::add_scaled`], so an OOC-held iterate stays
    /// bit-identical to a RAM-held one.
    pub fn add_scaled_volume(&self, other: &Volume, s: f32) -> anyhow::Result<()> {
        assert_eq!((other.nx, other.ny, other.nz), (self.nx, self.ny, self.nz));
        let plane = self.nx * self.ny;
        let mut buf = vec![0.0f32; self.store.slab_planes() * plane];
        let mut z0 = 0;
        while z0 < self.nz {
            let z1 = (z0 + self.store.slab_planes()).min(self.nz);
            let len = (z1 - z0) * plane;
            self.load_slab_into(z0, z1, &mut buf[..len])?;
            for (b, o) in buf[..len].iter_mut().zip(other.slab(z0, z1)) {
                *b += s * o;
            }
            self.store.store_planes(z0, z1, &buf[..len])?;
            z0 = z1;
        }
        Ok(())
    }
}

/// An out-of-core [`ProjectionSet`]: per-angle planes in the same
/// raw+sidecar format, with the shape mapped `(nu, nv, n_angles)` →
/// `(nx, ny, nz)` (angle-slowest storage *is* z-slowest storage, so the
/// formats coincide byte for byte). Angle chunks are contiguous file
/// ranges, streamed through the same budgeted [`SlabStore`].
#[derive(Debug)]
pub struct OocProjections {
    store: SlabStore,
    /// Detector columns.
    pub nu: usize,
    /// Detector rows.
    pub nv: usize,
    /// Number of angles.
    pub n_angles: usize,
}

impl OocProjections {
    /// Create a zero-filled OOC projection set.
    pub fn create(
        path: &Path,
        nu: usize,
        nv: usize,
        n_angles: usize,
        slab_angles: usize,
        budget_bytes: u64,
    ) -> anyhow::Result<OocProjections> {
        let store = SlabStore::create(path, nu * nv, n_angles, slab_angles, budget_bytes)?;
        write_sidecar(path, nu, nv, n_angles)?;
        Ok(OocProjections { store, nu, nv, n_angles })
    }

    /// Open an existing raw+sidecar projection set.
    pub fn open(
        path: &Path,
        slab_angles: usize,
        budget_bytes: u64,
    ) -> anyhow::Result<OocProjections> {
        let (nu, nv, n_angles) = read_sidecar(path)?;
        let store = SlabStore::open(path, nu * nv, n_angles, slab_angles, budget_bytes)?;
        Ok(OocProjections { store, nu, nv, n_angles })
    }

    /// Spill an in-RAM projection set to disk and open it as a store.
    pub fn from_projections(
        path: &Path,
        p: &ProjectionSet,
        slab_angles: usize,
        budget_bytes: u64,
    ) -> anyhow::Result<OocProjections> {
        let ooc = Self::create(path, p.nu, p.nv, p.n_angles, slab_angles, budget_bytes)?;
        ooc.store.store_planes(0, p.n_angles, &p.data)?;
        ooc.store.flush()?;
        Ok(ooc)
    }

    /// Materialize the whole set in RAM (parity tests, small sizes).
    pub fn to_projections(&self) -> anyhow::Result<ProjectionSet> {
        self.store.flush()?;
        let v = crate::io::load_volume(self.store.path())?;
        Ok(ProjectionSet { nu: v.nx, nv: v.ny, n_angles: v.nz, data: v.data })
    }

    /// Logical size in bytes (the file size).
    pub fn bytes(&self) -> u64 {
        self.store.total_bytes()
    }

    /// Host-RAM cache budget of the backing store.
    pub fn budget_bytes(&self) -> u64 {
        self.store.budget_bytes()
    }

    /// Cumulative traffic statistics of the backing store.
    pub fn stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Path of the backing file.
    pub fn path(&self) -> &Path {
        self.store.path()
    }

    /// Write every dirty cached slab back to disk.
    pub fn flush(&self) -> anyhow::Result<()> {
        self.store.flush()
    }

    /// Attach a fault injector to the backing store (see
    /// [`SlabStore::set_fault_plan`]).
    pub fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        self.store.set_fault_plan(plan);
    }

    /// Copy the angle chunk `[a0, a1)` into `dst` (length `(a1−a0)·nu·nv`).
    pub fn load_chunk_into(&self, a0: usize, a1: usize, dst: &mut [f32]) -> anyhow::Result<()> {
        self.store.load_planes_into(a0, a1, dst)
    }

    /// Write `src` (a whole number of angle planes) back at angle `a0`.
    pub fn store_chunk(&self, a0: usize, src: &[f32]) -> anyhow::Result<()> {
        let per = self.nu * self.nv;
        assert_eq!(src.len() % per, 0, "store_chunk: partial projection");
        self.store.store_planes(a0, a0 + src.len() / per, src)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phantom;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join("tigre_ooc_tests")
            .join(format!("{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn volume_spill_and_materialize_roundtrip() {
        let d = tmpdir("roundtrip");
        let v = phantom::shepp_logan(12);
        let ooc = OocVolume::from_volume(&d.join("v.raw"), &v, 3, 1 << 20).unwrap();
        assert_eq!(ooc.dims(), (12, 12, 12));
        assert_eq!(ooc.to_volume().unwrap(), v);
        // and the file doubles as a plain io::load_volume volume
        assert_eq!(crate::io::load_volume(&d.join("v.raw")).unwrap(), v);
        // cache-served materialization: the second read costs no disk I/O
        assert_eq!(ooc.read_volume().unwrap(), v);
        let loads = ooc.stats().loads;
        assert_eq!(ooc.read_volume().unwrap(), v);
        assert_eq!(ooc.stats().loads, loads, "repeat read_volume must hit the cache");
    }

    #[test]
    fn slab_loads_match_ram_slabs_at_every_alignment() {
        let d = tmpdir("align");
        let v = Volume::from_fn(5, 4, 11, |x, y, z| (x + 10 * y + 100 * z) as f32);
        // slab granularity 3 does not divide 11: ranges cross boundaries
        let ooc = OocVolume::from_volume(&d.join("v.raw"), &v, 3, 1 << 20).unwrap();
        let plane = 5 * 4;
        for z0 in 0..11 {
            for z1 in z0 + 1..=11 {
                let mut buf = vec![0.0; (z1 - z0) * plane];
                ooc.load_slab_into(z0, z1, &mut buf).unwrap();
                assert_eq!(&buf[..], v.slab(z0, z1), "range [{z0},{z1})");
            }
        }
    }

    #[test]
    fn budget_bounds_resident_bytes_with_lru_eviction() {
        let d = tmpdir("lru");
        let v = Volume::from_fn(4, 4, 12, |x, _, z| (x + z * 4) as f32);
        let plane_bytes = (4 * 4 * 4) as u64;
        // budget holds exactly two 2-slice slabs
        let budget = 4 * plane_bytes;
        let ooc = OocVolume::from_volume(&d.join("v.raw"), &v, 2, budget).unwrap();
        let mut buf = vec![0.0; 2 * 16];
        for z0 in [0usize, 2, 4, 6, 8, 10] {
            ooc.load_slab_into(z0, z0 + 2, &mut buf).unwrap();
            assert_eq!(&buf[..], v.slab(z0, z0 + 2));
            assert!(
                ooc.store.resident_bytes() <= budget,
                "resident {} > budget {budget}",
                ooc.store.resident_bytes()
            );
        }
        let s = ooc.stats();
        assert!(s.evictions >= 4, "6 slabs through a 2-slab budget: {s:?}");
        // re-reading the most recent slab is a pure cache hit
        let loads_before = ooc.stats().loads;
        ooc.load_slab_into(10, 12, &mut buf).unwrap();
        assert_eq!(ooc.stats().loads, loads_before, "hot slab must not re-read disk");
        assert_eq!(ooc.stats().hits, s.hits + 1);
    }

    #[test]
    fn dirty_slabs_write_back_on_eviction_and_flush() {
        let d = tmpdir("dirty");
        let plane_bytes = (3 * 3 * 4) as u64;
        let ooc = OocVolume::create(&d.join("v.raw"), 3, 3, 9, 1, 2 * plane_bytes).unwrap();
        let plane = 9;
        // write slabs 0..9 (1 slice each): budget of 2 forces evictions,
        // each of which must persist the dirty slab
        for z in 0..9usize {
            let data: Vec<f32> = (0..plane).map(|i| (z * 100 + i) as f32).collect();
            ooc.store_slab(z, &data).unwrap();
        }
        assert!(ooc.stats().evictions > 0);
        // unflushed tail slabs are still observable through the store...
        let mut buf = vec![0.0; plane];
        ooc.load_slab_into(4, 5, &mut buf).unwrap();
        assert_eq!(buf[0], 400.0);
        // ...including via the cache-served whole-volume read, which
        // observes dirty slabs without an explicit flush (evictions may
        // still write back along the way — that is the LRU's business)
        let rv = ooc.read_volume().unwrap();
        for z in 0..9 {
            assert_eq!(rv.at(0, 0, z), (z * 100) as f32, "read_volume slice {z}");
        }
        // ...and a flush makes the raw file complete for outside readers
        let w = ooc.to_volume().unwrap(); // flushes internally
        for z in 0..9 {
            assert_eq!(w.at(0, 0, z), (z * 100) as f32, "slice {z} lost");
        }
    }

    #[test]
    fn oversized_slabs_bypass_the_cache_but_stay_correct() {
        let d = tmpdir("bypass");
        let v = Volume::from_fn(4, 4, 8, |x, y, z| (x * y * z) as f32);
        // slab = 4 slices = 256 B, budget 100 B: every slab is stream-only
        let ooc = OocVolume::from_volume(&d.join("v.raw"), &v, 4, 100).unwrap();
        let mut buf = vec![0.0; 4 * 16];
        ooc.load_slab_into(2, 6, &mut buf).unwrap();
        assert_eq!(&buf[..], v.slab(2, 6));
        assert_eq!(ooc.store.resident_bytes(), 0, "bypass must not cache");
        // write-through path
        let patch = vec![7.0f32; 16];
        ooc.store_slab(3, &patch).unwrap();
        let w = ooc.to_volume().unwrap();
        assert!(w.slab(3, 4).iter().all(|&x| x == 7.0));
        assert_eq!(w.slab(2, 3), v.slab(2, 3), "neighbours untouched");
    }

    #[test]
    fn add_scaled_volume_matches_ram_add_scaled_bitwise() {
        let d = tmpdir("axpy");
        let mut x_ram = phantom::shepp_logan(10);
        let upd = Volume::from_fn(10, 10, 10, |x, y, z| (x + y + z) as f32 * 0.125);
        let ooc =
            OocVolume::from_volume(&d.join("x.raw"), &x_ram, 3, 2 * (10 * 10 * 3 * 4)).unwrap();
        ooc.add_scaled_volume(&upd, 0.3).unwrap();
        x_ram.add_scaled(&upd, 0.3);
        assert_eq!(ooc.to_volume().unwrap().data, x_ram.data, "streamed axpy must be bitwise");
    }

    #[test]
    fn open_rejects_size_and_sidecar_mismatches() {
        let d = tmpdir("badopen");
        let v = phantom::cube(4, 0.5, 1.0);
        let p = d.join("v.raw");
        crate::io::save_volume(&p, &v).unwrap();
        // truncated raw file
        let raw = fs::read(&p).unwrap();
        fs::write(&p, &raw[..raw.len() - 4]).unwrap();
        assert!(OocVolume::open(&p, 2, 1 << 20).is_err());
        fs::write(&p, &raw).unwrap();
        assert!(OocVolume::open(&p, 2, 1 << 20).is_ok());
        // sidecar with a missing dimension
        fs::write(p.with_extension("json"), "{\"nx\": 4, \"ny\": 4}").unwrap();
        let err = OocVolume::open(&p, 2, 1 << 20).unwrap_err();
        assert!(format!("{err:#}").contains("nz"), "{err:#}");
        // sidecar shape disagreeing with the raw length
        fs::write(p.with_extension("json"), "{\"nx\": 4, \"ny\": 4, \"nz\": 8}").unwrap();
        assert!(OocVolume::open(&p, 2, 1 << 20).is_err());
    }

    #[test]
    fn read_only_input_files_stream_but_reject_writes() {
        // measured projections often live on write-protected media: the
        // input-streaming use case must work with a read-only file
        let d = tmpdir("readonly");
        let v = phantom::shepp_logan(8);
        let p = d.join("v.raw");
        crate::io::save_volume(&p, &v).unwrap();
        let mut perms = fs::metadata(&p).unwrap().permissions();
        perms.set_readonly(true);
        fs::set_permissions(&p, perms.clone()).unwrap();

        let ooc = OocVolume::open(&p, 2, 1 << 20).unwrap();
        let mut buf = vec![0.0; 2 * 64];
        ooc.load_slab_into(3, 5, &mut buf).unwrap();
        assert_eq!(&buf[..], v.slab(3, 5));
        assert_eq!(ooc.read_volume().unwrap(), v);
        // writes are a typed error, up front (no deferred writeback trap)
        let err = ooc.store_slab(0, &[1.0; 64]).unwrap_err();
        assert!(format!("{err:#}").contains("read-only"), "{err:#}");

        perms.set_readonly(false);
        fs::set_permissions(&p, perms).unwrap();
    }

    #[test]
    fn projections_chunk_roundtrip_and_shape_mapping() {
        let d = tmpdir("proj");
        let mut p = ProjectionSet::zeros(5, 3, 7);
        for (i, v) in p.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let ooc = OocProjections::from_projections(&d.join("p.raw"), &p, 2, 1 << 20).unwrap();
        assert_eq!((ooc.nu, ooc.nv, ooc.n_angles), (5, 3, 7));
        let mut buf = vec![0.0; 2 * 15];
        ooc.load_chunk_into(3, 5, &mut buf).unwrap();
        assert_eq!(&buf[..], p.chunk(3, 5));
        assert_eq!(ooc.to_projections().unwrap(), p);
        // reopen through the sidecar (round-trips the shape mapping)
        drop(ooc);
        let re = OocProjections::open(&d.join("p.raw"), 3, 1 << 20).unwrap();
        assert_eq!((re.nu, re.nv, re.n_angles), (5, 3, 7));
        assert_eq!(re.to_projections().unwrap(), p);
    }

    #[test]
    fn concurrent_loads_from_worker_threads_are_consistent() {
        // the pipelined executor's loader lanes share the store across
        // threads; every thread must observe exactly the file's bytes
        let d = tmpdir("threads");
        let v = Volume::from_fn(6, 6, 12, |x, y, z| (x + 7 * y + 49 * z) as f32);
        let ooc = OocVolume::from_volume(&d.join("v.raw"), &v, 2, 3 * (6 * 6 * 2 * 4)).unwrap();
        std::thread::scope(|s| {
            for t in 0..4 {
                let ooc = &ooc;
                let v = &v;
                s.spawn(move || {
                    let plane = 36;
                    let mut buf = vec![0.0; 3 * plane];
                    for i in 0..30 {
                        let z0 = (t + i) % 9;
                        let z1 = z0 + 3;
                        ooc.load_slab_into(z0, z1, &mut buf).unwrap();
                        assert_eq!(&buf[..], v.slab(z0, z1));
                    }
                });
            }
        });
    }

    #[test]
    fn create_is_zero_filled_without_writes() {
        let d = tmpdir("zeros");
        let ooc = OocVolume::create(&d.join("z.raw"), 4, 4, 6, 2, 1 << 20).unwrap();
        assert_eq!(ooc.stats().bytes_written, 0, "sparse create writes nothing");
        let v = ooc.to_volume().unwrap();
        assert!(v.data.iter().all(|&x| x == 0.0));
        assert_eq!(v.data.len(), 96);
    }

    // -- disk fault injection & bounded retry (ISSUE 7) -------------------

    #[test]
    fn fault_disk_read_retries_then_succeeds() {
        let d = tmpdir("fault_retry_ok");
        let v = phantom::shepp_logan(8);
        let ooc = OocVolume::from_volume(&d.join("v.raw"), &v, 2, 1 << 20).unwrap();
        // first disk read fails MAX−1 times, then the real read runs
        let plan =
            Arc::new(FaultPlan::new().disk_io(0, MAX_DISK_ATTEMPTS - 1));
        plan.begin_op(FaultScope::Real);
        ooc.set_fault_plan(plan);
        let mut buf = vec![0.0; 2 * 64];
        ooc.load_slab_into(0, 2, &mut buf).unwrap();
        assert_eq!(&buf[..], v.slab(0, 2), "retried read must return the true bytes");
    }

    #[test]
    fn fault_disk_failure_past_retry_budget_is_a_typed_error() {
        let d = tmpdir("fault_retry_exhausted");
        let v = phantom::shepp_logan(8);
        let ooc = OocVolume::from_volume(&d.join("v.raw"), &v, 2, 1 << 20).unwrap();
        // enough injected failures to eat the whole retry budget
        let plan = Arc::new(FaultPlan::new().disk_io(0, MAX_DISK_ATTEMPTS));
        plan.begin_op(FaultScope::Real);
        ooc.set_fault_plan(plan);
        let mut buf = vec![0.0; 2 * 64];
        let err = ooc.load_slab_into(0, 2, &mut buf).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("disk read failed after"), "{msg}");
        assert!(msg.contains("injected disk fault"), "{msg}");
        // the store survives the error: the next (un-injected) read works
        ooc.load_slab_into(0, 2, &mut buf).unwrap();
        assert_eq!(&buf[..], v.slab(0, 2));
    }

    #[test]
    fn fault_truncated_file_read_is_a_typed_error() {
        // a real (non-injected) persistent failure: the file loses its
        // tail after open, so reads near the end hit UnexpectedEof on
        // every attempt and surface the typed error
        let d = tmpdir("fault_truncated");
        let v = phantom::shepp_logan(8);
        let p = d.join("v.raw");
        let ooc = OocVolume::from_volume(&p, &v, 2, 1 << 20).unwrap();
        fs::OpenOptions::new()
            .write(true)
            .open(&p)
            .unwrap()
            .set_len(64)
            .unwrap();
        let mut buf = vec![0.0; 2 * 64];
        let err = ooc.load_slab_into(6, 8, &mut buf).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("disk read failed after"), "{msg}");
    }

    #[test]
    fn degrade_disk_writeback_retries_then_succeeds() {
        // dirty-slab writeback survives transient write failures: the
        // flush write fails MAX−1 times, then the real write lands
        let d = tmpdir("degrade_wb_ok");
        let ooc = OocVolume::create(&d.join("v.raw"), 4, 4, 4, 2, 1 << 20).unwrap();
        let data: Vec<f32> = (0..32).map(|i| i as f32).collect();
        ooc.store_slab(0, &data).unwrap(); // dirty in cache, no disk op yet
        let plan = Arc::new(FaultPlan::new().disk_io(0, MAX_DISK_ATTEMPTS - 1));
        plan.begin_op(FaultScope::Real);
        ooc.set_fault_plan(plan);
        ooc.flush().unwrap();
        // the retried write persisted the true bytes
        let v = crate::io::load_volume(&d.join("v.raw")).unwrap();
        assert_eq!(&v.data[..32], &data[..], "retried writeback must persist true bytes");
    }

    #[test]
    fn degrade_disk_write_failure_past_retry_budget_is_a_typed_error() {
        let d = tmpdir("degrade_wb_exhausted");
        let ooc = OocVolume::create(&d.join("v.raw"), 4, 4, 4, 2, 1 << 20).unwrap();
        let data = vec![3.0f32; 32];
        ooc.store_slab(0, &data).unwrap();
        // enough injected failures to eat the whole retry budget
        let plan = Arc::new(FaultPlan::new().disk_io(0, MAX_DISK_ATTEMPTS));
        plan.begin_op(FaultScope::Real);
        ooc.set_fault_plan(plan);
        let err = ooc.flush().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("disk write failed after"), "{msg}");
        assert!(msg.contains("injected disk fault"), "{msg}");
        // the store survives: the slab is still dirty and a later
        // (un-injected) flush persists it
        ooc.flush().unwrap();
        let v = crate::io::load_volume(&d.join("v.raw")).unwrap();
        assert_eq!(&v.data[..32], &data[..]);
    }

    #[test]
    fn fault_sidecar_writes_are_atomic() {
        // the sidecar goes through temp-file + rename: after a create
        // the destination exists and no temp file is left behind
        let d = tmpdir("fault_sidecar");
        let p = d.join("v.raw");
        let _ooc = OocVolume::create(&p, 4, 4, 4, 2, 1 << 20).unwrap();
        assert!(p.with_extension("json").exists());
        assert!(!p.with_extension("json.tmp").exists());
        let (nx, ny, nz) = read_sidecar(&p).unwrap();
        assert_eq!((nx, ny, nz), (4, 4, 4));
    }
}
