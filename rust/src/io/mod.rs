//! File I/O: raw f32 volumes with JSON sidecar headers, 8-bit PGM slice
//! export (for the Fig. 10/11 image panels) and CSV series (for the
//! Fig. 7–9 curves).

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use crate::util::json::Json;
use crate::volume::{ProjectionSet, Volume};

/// Write a volume as little-endian raw f32 plus a `.json` sidecar with the
/// shape, so it can be reloaded or inspected with numpy
/// (`np.fromfile(...).reshape(nz, ny, nx)`).
pub fn save_volume(path: &Path, v: &Volume) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let mut f = fs::File::create(path)?;
    // f32 LE dump
    let mut buf = Vec::with_capacity(v.data.len() * 4);
    for x in &v.data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    let meta = Json::obj(vec![
        ("dtype", Json::str("f32le")),
        ("nx", Json::num(v.nx as f64)),
        ("ny", Json::num(v.ny as f64)),
        ("nz", Json::num(v.nz as f64)),
        ("order", Json::str("z-slowest (z,y,x)")),
    ]);
    fs::write(path.with_extension("json"), meta.pretty())?;
    Ok(())
}

/// Load a raw f32 volume using its JSON sidecar for the shape.
pub fn load_volume(path: &Path) -> anyhow::Result<Volume> {
    let meta_text = fs::read_to_string(path.with_extension("json"))?;
    let meta = Json::parse(&meta_text)?;
    let nx = meta.get("nx").and_then(Json::as_usize).ok_or_else(|| anyhow::anyhow!("missing nx"))?;
    let ny = meta.get("ny").and_then(Json::as_usize).ok_or_else(|| anyhow::anyhow!("missing ny"))?;
    let nz = meta.get("nz").and_then(Json::as_usize).ok_or_else(|| anyhow::anyhow!("missing nz"))?;
    let mut f = fs::File::open(path)?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    anyhow::ensure!(buf.len() == nx * ny * nz * 4, "raw size mismatch");
    let data: Vec<f32> = buf
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(Volume { nx, ny, nz, data })
}

/// Write a projection set in the same raw+sidecar format, mapping the
/// shape `(nu, nv, n_angles)` → `(nx, ny, nz)` (angle-slowest storage is
/// z-slowest storage; this is the mapping `volume::outofcore` uses, so
/// a saved set reopens as an `OocProjections` too).
pub fn save_projections(path: &Path, p: &ProjectionSet) -> anyhow::Result<()> {
    let v = Volume {
        nx: p.nu,
        ny: p.nv,
        nz: p.n_angles,
        data: p.data.clone(),
    };
    save_volume(path, &v)
}

/// Load a raw f32 projection set saved by [`save_projections`].
pub fn load_projections(path: &Path) -> anyhow::Result<ProjectionSet> {
    let v = load_volume(path)?;
    Ok(ProjectionSet { nu: v.nx, nv: v.ny, n_angles: v.nz, data: v.data })
}

/// Save one axial slice as an 8-bit binary PGM, windowed to [lo, hi]
/// (pass `None` to auto-window to the slice's own min/max).
pub fn save_slice_pgm(
    path: &Path,
    v: &Volume,
    z: usize,
    window: Option<(f32, f32)>,
) -> anyhow::Result<()> {
    anyhow::ensure!(z < v.nz, "slice {z} out of range (nz={})", v.nz);
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let slice = v.slab(z, z + 1);
    let (lo, hi) = window.unwrap_or_else(|| {
        let lo = slice.iter().cloned().fold(f32::MAX, f32::min);
        let hi = slice.iter().cloned().fold(f32::MIN, f32::max);
        (lo, if hi > lo { hi } else { lo + 1.0 })
    });
    let mut out = Vec::with_capacity(slice.len() + 64);
    out.extend_from_slice(format!("P5\n{} {}\n255\n", v.nx, v.ny).as_bytes());
    for &val in slice {
        let t = ((val - lo) / (hi - lo)).clamp(0.0, 1.0);
        out.push((t * 255.0).round() as u8);
    }
    fs::write(path, out)?;
    Ok(())
}

/// Write a CSV file from named columns (all columns must be equal length).
pub fn save_csv(path: &Path, headers: &[&str], columns: &[Vec<f64>]) -> anyhow::Result<()> {
    anyhow::ensure!(headers.len() == columns.len(), "csv header/column mismatch");
    let nrows = columns.first().map(|c| c.len()).unwrap_or(0);
    anyhow::ensure!(columns.iter().all(|c| c.len() == nrows), "ragged csv columns");
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let mut s = String::new();
    s.push_str(&headers.join(","));
    s.push('\n');
    for r in 0..nrows {
        let row: Vec<String> = columns.iter().map(|c| format!("{}", c[r])).collect();
        s.push_str(&row.join(","));
        s.push('\n');
    }
    fs::write(path, s)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phantom;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("tigre_io_tests").join(name);
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn volume_roundtrip() {
        let d = tmpdir("vol");
        let v = phantom::shepp_logan(12);
        let p = d.join("v.raw");
        save_volume(&p, &v).unwrap();
        let w = load_volume(&p).unwrap();
        assert_eq!(v, w);
    }

    #[test]
    fn load_rejects_size_mismatch() {
        let d = tmpdir("bad");
        let v = phantom::cube(4, 0.5, 1.0);
        let p = d.join("v.raw");
        save_volume(&p, &v).unwrap();
        // truncate the raw file
        let raw = fs::read(&p).unwrap();
        fs::write(&p, &raw[..raw.len() - 4]).unwrap();
        let err = load_volume(&p).unwrap_err();
        assert!(format!("{err:#}").contains("size mismatch"), "{err:#}");
        // and an *extended* file is just as invalid (shape must be exact)
        let mut grown = raw.clone();
        grown.extend_from_slice(&[0u8; 8]);
        fs::write(&p, &grown).unwrap();
        assert!(load_volume(&p).is_err());
        // restoring the original bytes restores loadability
        fs::write(&p, &raw).unwrap();
        assert_eq!(load_volume(&p).unwrap(), v);
    }

    #[test]
    fn load_rejects_missing_or_malformed_sidecar() {
        let d = tmpdir("sidecar");
        let v = phantom::cube(4, 0.5, 1.0);
        let p = d.join("v.raw");
        save_volume(&p, &v).unwrap();
        let sidecar = p.with_extension("json");
        let good = fs::read_to_string(&sidecar).unwrap();
        // missing sidecar entirely
        fs::remove_file(&sidecar).unwrap();
        assert!(load_volume(&p).is_err());
        // sidecar that is not JSON
        fs::write(&sidecar, "not json at all").unwrap();
        assert!(load_volume(&p).is_err());
        // sidecar missing a dimension
        fs::write(&sidecar, "{\"nx\": 4, \"ny\": 4}").unwrap();
        let err = load_volume(&p).unwrap_err();
        assert!(format!("{err:#}").contains("nz"), "{err:#}");
        // non-integer dimension
        fs::write(&sidecar, "{\"nx\": 4, \"ny\": 4, \"nz\": 4.5}").unwrap();
        assert!(load_volume(&p).is_err());
        fs::write(&sidecar, good).unwrap();
        assert_eq!(load_volume(&p).unwrap(), v);
    }

    #[test]
    fn load_rejects_sidecar_shape_disagreeing_with_raw_length() {
        // the OOC store trusts this format; a sidecar claiming a bigger
        // volume than the raw file holds must be a hard error, not a
        // short read
        let d = tmpdir("shape");
        let v = phantom::cube(4, 0.5, 1.0);
        let p = d.join("v.raw");
        save_volume(&p, &v).unwrap();
        fs::write(
            p.with_extension("json"),
            "{\"dtype\": \"f32le\", \"nx\": 4, \"ny\": 4, \"nz\": 8}",
        )
        .unwrap();
        assert!(load_volume(&p).is_err());
    }

    #[test]
    fn projections_roundtrip() {
        let d = tmpdir("proj");
        let mut p = ProjectionSet::zeros(5, 3, 7);
        for (i, v) in p.data.iter_mut().enumerate() {
            *v = (i as f32).sin();
        }
        let path = d.join("p.raw");
        save_projections(&path, &p).unwrap();
        assert_eq!(load_projections(&path).unwrap(), p);
    }

    #[test]
    fn pgm_header_and_size() {
        let d = tmpdir("pgm");
        let v = phantom::shepp_logan(16);
        let p = d.join("slice.pgm");
        save_slice_pgm(&p, &v, 8, None).unwrap();
        let bytes = fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P5\n16 16\n255\n"));
        assert_eq!(bytes.len(), 13 + 256);
    }

    #[test]
    fn pgm_out_of_range_slice_errors() {
        let d = tmpdir("pgm2");
        let v = phantom::cube(4, 0.5, 1.0);
        assert!(save_slice_pgm(&d.join("x.pgm"), &v, 99, None).is_err());
    }

    #[test]
    fn csv_writes_rows() {
        let d = tmpdir("csv");
        let p = d.join("series.csv");
        save_csv(&p, &["n", "t"], &[vec![1.0, 2.0], vec![0.5, 0.25]]).unwrap();
        let text = fs::read_to_string(&p).unwrap();
        assert_eq!(text, "n,t\n1,0.5\n2,0.25\n");
    }

    #[test]
    fn csv_rejects_ragged() {
        let d = tmpdir("csv2");
        assert!(save_csv(&d.join("x.csv"), &["a", "b"], &[vec![1.0], vec![]]).is_err());
    }
}
