// Seeded violation for the `backend-match` lint: checked under the
// pretend path rust/src/algorithms/fixture.rs. Never compiled.

pub enum Backend {
    Native,
    Pjrt,
}

pub fn wildcard_arm(backend: &Backend) -> u32 {
    match backend {
        Backend::Native => 1,
        _ => 0,
    }
}

pub fn missing_injection_arms(backend: &Backend) -> u32 {
    match backend {
        Backend::Native => 1,
        Backend::Pjrt => 2,
    }
}

pub fn tuple_scrutinee_is_exempt(backend: &Backend, flag: bool) -> u32 {
    // dispatches through the executor's own Backend match downstream:
    // must NOT be reported
    match (backend, flag) {
        (Backend::Native, true) => 1,
        _ => 0,
    }
}
