//! Integration: the AOT-compiled Pallas/JAX artifacts (PJRT backend)
//! against the native rust kernels, and through the full coordinator.
//!
//! Requires `make artifacts` to have produced `artifacts/manifest.json`;
//! when artifacts are missing the tests are skipped (pass vacuously) so
//! `cargo test` works in a fresh checkout.

use std::path::PathBuf;

use tigre::coordinator::{Backend, ExecMode, MultiGpu};
use tigre::geometry::Geometry;
use tigre::kernels::{BackprojWeight, Projector};
use tigre::metrics;
use tigre::phantom;
use tigre::runtime::Manifest;
use tigre::volume::ProjectionSet;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let m = Manifest::load(&dir).ok()?;
    if m.entries.is_empty() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    } else {
        Some(dir)
    }
}

#[test]
fn pjrt_forward_close_to_native_joseph() {
    let Some(dir) = artifacts_dir() else { return };
    let g = Geometry::cone_beam(32, 8);
    let v = phantom::shepp_logan(32);
    let pjrt = tigre::runtime::pjrt::try_forward(&dir, &g, &v)
        .expect("pjrt forward")
        .expect("manifest should contain fp 32/8");
    // The artifact implements the interpolated (Joseph) projector; the
    // native Joseph kernel is the right comparator.
    let native = tigre::kernels::forward(&g, &v, Projector::Joseph, 2);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in native.data.iter().zip(&pjrt.data) {
        num += ((a - b) as f64).powi(2);
        den += (*a as f64).powi(2);
    }
    let rel = (num / den.max(1e-12)).sqrt();
    assert!(rel < 0.05, "pjrt vs native joseph rel error {rel}");
}

#[test]
fn pjrt_backward_close_to_native_fdk() {
    let Some(dir) = artifacts_dir() else { return };
    let g = Geometry::cone_beam(32, 8);
    let v = phantom::shepp_logan(32);
    let p = tigre::kernels::forward(&g, &v, Projector::Siddon, 2);
    let pjrt = tigre::runtime::pjrt::try_backward(&dir, &g, &p, BackprojWeight::Fdk)
        .expect("pjrt backward")
        .expect("manifest should contain bp 32/8");
    let native = tigre::kernels::backward(&g, &p, BackprojWeight::Fdk, 2);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in native.data.iter().zip(&pjrt.data) {
        num += ((a - b) as f64).powi(2);
        den += (*a as f64).powi(2);
    }
    let rel = (num / den.max(1e-12)).sqrt();
    assert!(rel < 1e-3, "pjrt vs native fdk backprojection rel error {rel}");
}

#[test]
fn pjrt_unknown_shape_falls_back_to_native() {
    let Some(dir) = artifacts_dir() else { return };
    let g = Geometry::cone_beam(20, 5); // not in the manifest
    let v = phantom::cube(20, 0.5, 1.0);
    let out = tigre::runtime::forward_or_native(&dir, &g, &v, 2);
    let native = tigre::kernels::forward(&g, &v, Projector::Siddon, 2);
    assert_eq!(out.data, native.data, "fallback must be exactly native");
}

#[test]
fn coordinator_full_mode_with_pjrt_backend() {
    let Some(dir) = artifacts_dir() else { return };
    let g = Geometry::cone_beam(32, 16);
    let v = phantom::shepp_logan(32);
    let ctx = MultiGpu::gtx1080ti(2)
        .with_backend(Backend::Pjrt { artifacts_dir: dir, weight: BackprojWeight::Fdk, threads: 2 });
    let (proj, stats) = ctx.forward(&g, Some(&v), ExecMode::Full).unwrap();
    let proj = proj.unwrap();
    assert_eq!(stats.splits_per_device, 1);
    assert!(proj.norm2() > 0.0);
    // and a backprojection through the same backend
    let (vol, _) = ctx.backward(&g, Some(&proj), ExecMode::Full).unwrap();
    let vol = vol.unwrap();
    // recon-ish sanity: centre > edge
    assert!(vol.at(16, 16, 16) > vol.at(0, 16, 16));
}

#[test]
fn pjrt_respects_detector_offset() {
    // panel-shift: the offset detector artifact path must match native
    let Some(dir) = artifacts_dir() else { return };
    let mut g = Geometry::cone_beam(32, 8);
    g.offset_det[0] = 3.0;
    let v = phantom::shepp_logan(32);
    let pjrt = tigre::runtime::pjrt::try_forward(&dir, &g, &v)
        .expect("pjrt forward")
        .expect("entry exists");
    let native = tigre::kernels::forward(&g, &v, Projector::Joseph, 2);
    let corr = {
        let a = tigre::volume::Volume { nx: pjrt.data.len(), ny: 1, nz: 1, data: pjrt.data.clone() };
        let b = tigre::volume::Volume { nx: native.data.len(), ny: 1, nz: 1, data: native.data.clone() };
        metrics::correlation(&a, &b)
    };
    assert!(corr > 0.999, "offset-detector correlation {corr}");
    let _ = ProjectionSet::zeros(1, 1, 1);
}
