"""AOT lowering: jax (L2, calling the L1 Pallas kernels) -> HLO text +
manifest, consumed by `rust/src/runtime/`.

HLO *text* is the interchange format — NOT `lowered.compiler_ir(...)
.serialize()`: the image's xla_extension 0.5.1 rejects jax>=0.5 protos
(64-bit instruction ids); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run as: `cd python && python -m compile.aot --out-dir ../artifacts`.
`make artifacts` skips this when inputs are unchanged.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# The manifest shape set: (n, angles) cubic problems. Small shapes keep
# AOT + rust-side compile times reasonable; anything else falls back to
# the native rust kernels (runtime::forward_or_native).
SHAPES = [
    (16, 8),
    (32, 8),
    (32, 16),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forward(n, a):
    vol = jax.ShapeDtypeStruct((n, n, n), jnp.float32)
    params = jax.ShapeDtypeStruct((12,), jnp.float32)
    angles = jax.ShapeDtypeStruct((a,), jnp.float32)

    def fn(vol, params, angles):
        return (model.forward(vol, params, angles, nu=n, nv=n),)

    return jax.jit(fn).lower(vol, params, angles)


def lower_backward(n, a, matched=False):
    proj = jax.ShapeDtypeStruct((a, n, n), jnp.float32)
    params = jax.ShapeDtypeStruct((12,), jnp.float32)
    angles = jax.ShapeDtypeStruct((a,), jnp.float32)

    def fn(proj, params, angles):
        return (
            model.backward(proj, params, angles, nx=n, ny=n, nz=n, matched=matched),
        )

    return jax.jit(fn).lower(proj, params, angles)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    lowerings = [
        ("forward", "fp", lambda n, a: lower_forward(n, a)),
        ("backward", "bp", lambda n, a: lower_backward(n, a, matched=False)),
        ("backward_matched", "bpm", lambda n, a: lower_backward(n, a, matched=True)),
    ]
    for n, a in SHAPES:
        for op, prefix, lower in lowerings:
            name = f"{prefix}_n{n}_a{a}"
            fname = f"{name}.hlo.txt"
            text = to_hlo_text(lower(n, a))
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            entries.append(
                {
                    "name": name,
                    "op": op,
                    "nx": n,
                    "ny": n,
                    "nz": n,
                    "nu": n,
                    "nv": n,
                    "angles": a,
                    "file": fname,
                }
            )
            print(f"wrote {fname} ({len(text)} chars)")

    manifest = {"version": 1, "entries": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json with {len(entries)} entries")


if __name__ == "__main__":
    main()
