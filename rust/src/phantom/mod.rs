//! Synthetic phantoms.
//!
//! The paper's showcase reconstructions use proprietary scans (a roasted
//! coffee bean on a Zeiss Xradia, an Ichthyosaur fossil on a Nikon bay).
//! Those are substituted here by analytic ellipsoid phantoms that exercise
//! the same code paths: the 3-D Shepp–Logan head, a layered "bean" and an
//! asymmetric multi-body "fossil" (see DESIGN.md §2 for the substitution
//! rationale).

pub mod noise;

use crate::util::pcg::Pcg32;
use crate::volume::Volume;

/// An ellipsoid: centre, semi-axes, in-plane rotation, additive density.
#[derive(Clone, Copy, Debug)]
pub struct Ellipsoid {
    /// Centre in normalized [-1, 1] coordinates.
    pub center: [f64; 3],
    /// Semi-axes (a, b, c) in normalized [-1, 1] coordinates.
    pub axes: [f64; 3],
    /// Rotation about the z axis, radians.
    pub phi: f64,
    /// Additive attenuation contribution.
    pub density: f32,
}

impl Ellipsoid {
    /// True if the normalized point `(x, y, z)` lies inside.
    #[inline]
    pub fn contains(&self, x: f64, y: f64, z: f64) -> bool {
        let (s, c) = self.phi.sin_cos();
        let dx = x - self.center[0];
        let dy = y - self.center[1];
        let dz = z - self.center[2];
        let rx = c * dx + s * dy;
        let ry = -s * dx + c * dy;
        let q = (rx / self.axes[0]).powi(2)
            + (ry / self.axes[1]).powi(2)
            + (dz / self.axes[2]).powi(2);
        q <= 1.0
    }
}

/// Rasterize a set of additive ellipsoids into an `nx × ny × nz` volume.
/// Voxel centres are mapped to normalized coordinates `[-1, 1]³`.
pub fn rasterize(ellipsoids: &[Ellipsoid], nx: usize, ny: usize, nz: usize) -> Volume {
    let mut v = Volume::zeros(nx, ny, nz);
    for z in 0..nz {
        let pz = (2.0 * (z as f64 + 0.5) / nz as f64) - 1.0;
        for y in 0..ny {
            let py = (2.0 * (y as f64 + 0.5) / ny as f64) - 1.0;
            for x in 0..nx {
                let px = (2.0 * (x as f64 + 0.5) / nx as f64) - 1.0;
                let mut val = 0.0f32;
                for e in ellipsoids {
                    if e.contains(px, py, pz) {
                        val += e.density;
                    }
                }
                v.data[(z * ny + y) * nx + x] = val;
            }
        }
    }
    v
}

/// The classic 3-D Shepp–Logan head phantom (Kak & Slaney variant with
/// boosted contrast for visualization, as TIGRE ships it).
pub fn shepp_logan_ellipsoids() -> Vec<Ellipsoid> {
    // (a, b, c, x0, y0, z0, phi_deg, density)
    const T: [(f64, f64, f64, f64, f64, f64, f64, f32); 10] = [
        (0.690, 0.920, 0.810, 0.0, 0.0, 0.0, 0.0, 1.0),
        (0.662, 0.874, 0.780, 0.0, -0.0184, 0.0, 0.0, -0.8),
        (0.110, 0.310, 0.220, 0.22, 0.0, 0.0, -18.0, -0.2),
        (0.160, 0.410, 0.280, -0.22, 0.0, 0.0, 18.0, -0.2),
        (0.210, 0.250, 0.410, 0.0, 0.35, -0.15, 0.0, 0.1),
        (0.046, 0.046, 0.050, 0.0, 0.1, 0.25, 0.0, 0.1),
        (0.046, 0.046, 0.050, 0.0, -0.1, 0.25, 0.0, 0.1),
        (0.046, 0.023, 0.050, -0.08, -0.605, 0.0, 0.0, 0.1),
        (0.023, 0.023, 0.020, 0.0, -0.606, 0.0, 0.0, 0.1),
        (0.023, 0.046, 0.020, 0.06, -0.605, 0.0, 0.0, 0.1),
    ];
    T.iter()
        .map(|&(a, b, c, x0, y0, z0, phi, d)| Ellipsoid {
            center: [x0, y0, z0],
            axes: [a, b, c],
            phi: phi.to_radians(),
            density: d,
        })
        .collect()
}

/// 3-D Shepp–Logan phantom rasterized at `n³` (cubic) resolution.
pub fn shepp_logan(n: usize) -> Volume {
    rasterize(&shepp_logan_ellipsoids(), n, n, n)
}

/// "Coffee bean" phantom: an ellipsoidal shell with a lower-density
/// interior and a central crease, mimicking the bean scanned in §3.2.
pub fn bean_ellipsoids() -> Vec<Ellipsoid> {
    vec![
        // outer hull
        Ellipsoid { center: [0.0, 0.0, 0.0], axes: [0.62, 0.42, 0.38], phi: 0.35, density: 1.0 },
        // interior (less dense endosperm)
        Ellipsoid { center: [0.0, 0.0, 0.0], axes: [0.54, 0.34, 0.30], phi: 0.35, density: -0.55 },
        // the crease: a thin low-density slit through the middle
        Ellipsoid { center: [0.0, 0.0, 0.0], axes: [0.50, 0.045, 0.26], phi: 0.35, density: -0.35 },
        // a couple of internal cracks
        Ellipsoid { center: [0.18, 0.12, 0.05], axes: [0.16, 0.02, 0.10], phi: 0.9, density: -0.3 },
        Ellipsoid { center: [-0.2, -0.1, -0.08], axes: [0.12, 0.02, 0.08], phi: -0.5, density: -0.3 },
    ]
}

/// Bean phantom at `nx × ny × nz` (the paper's bean volume is strongly
/// anisotropic: 3340 × 3340 × 900).
pub fn bean(nx: usize, ny: usize, nz: usize) -> Volume {
    rasterize(&bean_ellipsoids(), nx, ny, nz)
}

/// "Fossil" phantom: dense elongated bodies (fin bones) embedded in a
/// lighter matrix slab, asymmetric like the 3360 × 900 × 2000 Ichthyosaur
/// volume of §3.2. Deterministic for a given seed.
pub fn fossil_ellipsoids(seed: u64) -> Vec<Ellipsoid> {
    let mut rng = Pcg32::new(seed);
    let mut es = vec![
        // rock matrix slab
        Ellipsoid { center: [0.0, 0.0, 0.0], axes: [0.9, 0.55, 0.8], phi: 0.0, density: 0.3 },
    ];
    // a fan of phalange-like dense bodies
    for i in 0..14 {
        let t = i as f64 / 13.0;
        let angle = -0.5 + t; // fan out
        let cx = -0.55 + 1.05 * t;
        let cy = -0.25 + 0.45 * (t - 0.5).abs();
        let len = 0.16 + 0.1 * rng.next_f64();
        es.push(Ellipsoid {
            center: [cx, cy, -0.2 + 0.4 * t],
            axes: [len, 0.045 + 0.02 * rng.next_f64(), 0.05],
            phi: angle,
            density: 0.9 + 0.2 * rng.next_f32(),
        });
    }
    // vertebra-like spheres along a curve
    for i in 0..8 {
        let t = i as f64 / 7.0;
        es.push(Ellipsoid {
            center: [-0.6 + 1.2 * t, 0.3 + 0.1 * (6.0 * t).sin(), 0.35],
            axes: [0.06, 0.06, 0.06],
            phi: 0.0,
            density: 1.1,
        });
    }
    es
}

/// Fossil phantom at `nx × ny × nz`.
pub fn fossil(nx: usize, ny: usize, nz: usize, seed: u64) -> Volume {
    rasterize(&fossil_ellipsoids(seed), nx, ny, nz)
}

/// A centred cube of the given half-width (fraction of the volume) — the
/// simplest possible phantom, used by unit tests with known line integrals.
pub fn cube(n: usize, half_frac: f64, density: f32) -> Volume {
    let c = (n as f64 - 1.0) / 2.0;
    let half = half_frac * n as f64 / 2.0;
    Volume::from_fn(n, n, n, |x, y, z| {
        let inside = ((x as f64) - c).abs() <= half
            && ((y as f64) - c).abs() <= half
            && ((z as f64) - c).abs() <= half;
        if inside {
            density
        } else {
            0.0
        }
    })
}

/// Uniform random noise volume in [0, 1) — workload generator for
/// property tests and benches.
pub fn random(nx: usize, ny: usize, nz: usize, seed: u64) -> Volume {
    let mut rng = Pcg32::new(seed);
    let mut v = Volume::zeros(nx, ny, nz);
    for x in &mut v.data {
        *x = rng.next_f32();
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shepp_logan_structure() {
        let v = shepp_logan(32);
        // outer shell value 1.0 appears; centre is inside skull (≈0.2)
        let max = v.data.iter().cloned().fold(f32::MIN, f32::max);
        assert!(max >= 0.95 && max <= 1.35, "max {max}");
        let c = v.at(16, 16, 16);
        assert!((c - 0.2).abs() < 0.15, "centre {c}");
        // corners are air
        assert_eq!(v.at(0, 0, 0), 0.0);
        assert_eq!(v.at(31, 31, 31), 0.0);
    }

    #[test]
    fn shepp_logan_known_regions() {
        let v = shepp_logan(33);
        // inside the big "ventricle" ellipsoids (x=±0.22) the value drops
        // to ~0 (1.0 − 0.8 − 0.2); between them it is the brain value 0.2.
        let c = 16; // centre index
        let at_norm = |nx: f64| ((nx + 1.0) * 33.0 / 2.0 - 0.5).round() as usize;
        let left = v.at(at_norm(-0.22), c, c);
        let right = v.at(at_norm(0.22), c, c);
        assert!(left.abs() < 0.05, "left ventricle {left}");
        assert!(right.abs() < 0.05, "right ventricle {right}");
        assert!((v.at(c, c, c) - 0.2).abs() < 0.05, "brain matter");
    }

    #[test]
    fn cube_line_integrals_known() {
        let v = cube(16, 0.5, 2.0);
        // the central column should have exactly 8 voxels of density 2
        let mut col = 0.0;
        for z in 0..16 {
            col += v.at(8, 8, z);
        }
        assert!((col - 16.0).abs() < 1e-6, "col {col}");
    }

    #[test]
    fn bean_has_shell_and_crease() {
        let v = bean(48, 48, 48);
        let max = v.data.iter().cloned().fold(f32::MIN, f32::max);
        assert!((max - 1.0).abs() < 1e-6);
        // interior value below shell value
        let interior = v.at(24, 24, 24);
        assert!(interior < 0.5, "interior {interior}");
        assert!(v.data.iter().any(|&x| x > 0.0), "non-empty");
    }

    #[test]
    fn fossil_deterministic_and_asymmetric() {
        let a = fossil(24, 12, 20, 7);
        let b = fossil(24, 12, 20, 7);
        assert_eq!(a.data, b.data);
        let c = fossil(24, 12, 20, 8);
        assert_ne!(a.data, c.data);
        // bones denser than matrix
        let max = a.data.iter().cloned().fold(f32::MIN, f32::max);
        assert!(max > 1.0);
    }

    #[test]
    fn rasterize_respects_rotation() {
        // A long thin ellipsoid rotated 90° should extend along y, not x.
        let e = Ellipsoid {
            center: [0.0, 0.0, 0.0],
            axes: [0.8, 0.1, 0.1],
            phi: std::f64::consts::FRAC_PI_2,
            density: 1.0,
        };
        let v = rasterize(&[e], 21, 21, 21);
        assert!(v.at(10, 3, 10) > 0.0, "extends along +y");
        assert_eq!(v.at(3, 10, 10), 0.0, "does not extend along +x");
    }

    #[test]
    fn random_is_seeded() {
        assert_eq!(random(4, 4, 4, 3).data, random(4, 4, 4, 3).data);
        assert_ne!(random(4, 4, 4, 3).data, random(4, 4, 4, 4).data);
    }
}
